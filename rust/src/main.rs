//! `pipeit` — Pipe-it CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   tables                         print every paper table/figure (paper-vs-ours)
//!   explore   --net N [--predicted] [--replicated [--max-replicas R]]
//!   predict   --net N              dump the layer x config time matrix
//!   simulate  --net N --pipeline P [--images I] [--queue-cap C]
//!   count     [--net N]            design-space sizes (Eq. 1-2 + replicated)
//!   serve     --net N [--replicas R] ...   simulated-time fleet serving
//!   serve     --artifacts DIR [--replicas R] ...  real PJRT serving
//!
//! All simulator-backed subcommands accept `--platform configs/<f>.json`.

use anyhow::{Context, Result};

use pipeit::cnn::zoo;
use pipeit::config::Config;
use pipeit::coordinator;
use pipeit::coordinator::{run_fleet, synthetic_fleet};
use pipeit::dse;
use pipeit::perfmodel::{PerfModel, TimeMatrix};
use pipeit::reports::Reporter;
use pipeit::runtime::Manifest;
use pipeit::simulator::pipeline_sim;
use pipeit::util::cli::Args;
use pipeit::util::table::{f, Table};

const USAGE: &str = "\
pipeit — Pipe-it: high-throughput CNN inference on big.LITTLE (TCAD'19 reproduction)

USAGE: pipeit <tables|explore|predict|simulate|count|serve> [options]

  tables     [--platform F]                 regenerate every paper table & figure
  explore    --net N [--predicted] [--platform F]
             [--replicated] [--max-replicas 4]   also search replica partitions
  predict    --net N [--platform F]         per-layer time matrix (ms)
  simulate   --net N --pipeline B4-s2-s2 [--images 500] [--queue-cap 2]
  count      [--net N] [--max-replicas 4]   design-space sizes (Eq. 1-2 + fleet)
  serve      --net N [--replicas 1] [--images 60] [--queue-cap 2]
             [--time-scale 0.1]              simulated-time fleet serving
                                             (deterministic; no seed)
  serve      --artifacts artifacts/pipenet_tiny [--replicas 1] [--images 50]
             [--batch 1] [--stages 3] [--queue-cap 2] [--serial] [--seed 7]
                                            real PJRT serving (needs --features pjrt)

networks: alexnet googlenet mobilenet resnet50 squeezenet";

fn net_arg(args: &Args) -> Result<pipeit::cnn::Network> {
    let name = args.get("net").context("--net is required")?;
    zoo::by_name(name).with_context(|| format!("unknown network {name:?}"))
}

/// One line per replica of a replicated design (shared by
/// `explore --replicated` and `serve --net`).
fn print_replicas(design: &dse::ReplicatedDesign) {
    for (i, rep) in design.replicas.iter().enumerate() {
        println!(
            "  replica {i}: {:<6} {}  alloc {}  {:.2} imgs/s",
            rep.budget.to_string(),
            rep.point.pipeline,
            rep.point.allocation.display_1based(),
            rep.point.throughput
        );
    }
}

fn main() -> Result<()> {
    let args = Args::parse(
        std::env::args().skip(1),
        &["predicted", "serial", "measured", "replicated"],
    );
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };
    let cfg = Config::load_or_default(args.get("platform"))?;

    match cmd {
        "tables" => {
            Reporter::new(cfg).print_all();
        }
        "explore" => {
            let net = net_arg(&args)?;
            let (hb, hs) = (cfg.platform.big.cores, cfg.platform.small.cores);
            let tm = if args.has_flag("predicted") {
                let model = PerfModel::fit(&cfg.platform);
                TimeMatrix::predicted(&cfg.platform, &model, &net)
            } else {
                TimeMatrix::measured(&cfg.platform, &net)
            };
            let pt = dse::explore(&tm, hb, hs);
            println!("network    : {}", net.name);
            println!("pipeline   : {}", pt.pipeline);
            println!("allocation : {}", pt.allocation.display_1based());
            println!("throughput : {:.2} imgs/s (Eq. 12)", pt.throughput);
            let times = dse::point_stage_times(&tm, &pt);
            for (i, (s, t)) in pt.pipeline.stages.iter().zip(&times).enumerate() {
                println!("  stage {i}: {s}  {:.1} ms", t * 1e3);
            }
            if args.has_flag("replicated") {
                let max_r = args.get_usize("max-replicas", 4)?;
                let fleet = dse::explore_replicated(&tm, hb, hs, max_r);
                println!();
                println!(
                    "replicated : {} (R={})",
                    fleet.partition_display(),
                    fleet.num_replicas()
                );
                print_replicas(&fleet);
                println!(
                    "aggregate  : {:.2} imgs/s ({:+.1}% vs best single pipeline)",
                    fleet.throughput,
                    100.0 * (fleet.throughput / pt.throughput - 1.0)
                );
                let sim =
                    pipeline_sim::simulate_replicated(&fleet.stage_times(&tm), 1000, 2);
                println!("simulated  : {:.2} imgs/s (DES, 1000 images)", sim.throughput);
            }
        }
        "predict" => {
            let net = net_arg(&args)?;
            let model = PerfModel::fit(&cfg.platform);
            let tm = TimeMatrix::predicted(&cfg.platform, &model, &net);
            let mut t = Table::new(
                &format!("{} predicted layer times (ms)", net.name),
                &["layer", "B1", "B2", "B3", "B4", "s1", "s2", "s3", "s4"],
            );
            for (j, name) in tm.layer_names.iter().enumerate() {
                let mut row = vec![name.clone()];
                for ci in 0..tm.configs.len() {
                    row.push(f(tm.layer(j, ci) * 1e3, 2));
                }
                t.row(row);
            }
            t.print();
        }
        "simulate" => {
            let net = net_arg(&args)?;
            let spec = args.get("pipeline").context("--pipeline required (e.g. B4-s2-s2)")?;
            let p = dse::PipelineConfig::parse(spec)?;
            anyhow::ensure!(
                p.is_valid(cfg.platform.big.cores, cfg.platform.small.cores),
                "pipeline exceeds platform core budget"
            );
            let tm = TimeMatrix::measured(&cfg.platform, &net);
            let alloc = dse::work_flow(&tm, &p, tm.num_layers());
            let times = dse::stage_times(&tm, &p, &alloc);
            let images = args.get_usize("images", 500)?;
            let cap = args.get_usize("queue-cap", 2)?;
            let sim = pipeline_sim::simulate(&times, images, cap);
            println!("network    : {}", net.name);
            println!("pipeline   : {p}");
            println!("allocation : {}", alloc.display_1based());
            println!(
                "eq12 tp    : {:.2} imgs/s",
                pipeline_sim::steady_state_throughput(&times)
            );
            println!(
                "sim tp     : {:.2} imgs/s over {images} images (cap {cap})",
                sim.throughput
            );
            println!("bottleneck : stage {}", sim.bottleneck);
            for (i, u) in sim.utilization.iter().enumerate() {
                println!("  stage {i} utilization {:.0}%", 100.0 * u);
            }
        }
        "count" => {
            let (hb, hs) = (cfg.platform.big.cores, cfg.platform.small.cores);
            println!(
                "pipelines on {}B+{}s: {}",
                hb,
                hs,
                dse::count::total_pipelines(hb, hs)
            );
            let max_r = args.get_usize("max-replicas", 4)?;
            println!(
                "replicated (R<={max_r}): {} core partitions, {} fleet pipelines",
                dse::count::core_partitions(hb, hs, max_r),
                dse::count::replicated_pipelines(hb, hs, max_r)
            );
            let nets = match args.get("net") {
                Some(_) => vec![net_arg(&args)?],
                None => zoo::all_networks(),
            };
            for net in nets {
                println!(
                    "{:<11} W={:<3} design points = {}",
                    net.name,
                    net.num_layers(),
                    dse::count::design_points(net.num_layers(), hb, hs)
                );
            }
        }
        "serve" => {
            let replicas = args.get_usize("replicas", 1)?;
            anyhow::ensure!(replicas >= 1, "--replicas must be >= 1");
            if let Some(dir) = args.get("artifacts") {
                serve_artifacts(&args, dir, replicas)?;
            } else if args.get("net").is_some() {
                serve_simulated(&args, &cfg, replicas)?;
            } else {
                anyhow::bail!(
                    "serve needs --net N (simulated-time fleet) or --artifacts DIR \
                     (real PJRT serving)\n\n{USAGE}"
                );
            }
        }
        other => {
            println!("unknown subcommand {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Simulated-time serving: pick the best R-replica design for the network,
/// then drive the REAL thread fleet (shared admission queue, LOW dispatch)
/// with synthetic stages that sleep for the predicted stage service times,
/// scaled by `--time-scale`. Runs in every build — no PJRT required — and
/// prints wall-clock numbers next to the DES prediction.
fn serve_simulated(args: &Args, cfg: &Config, replicas: usize) -> Result<()> {
    anyhow::ensure!(
        !args.has_flag("serial"),
        "--serial applies to --artifacts serving only"
    );
    for key in ["batch", "stages", "seed"] {
        anyhow::ensure!(
            args.get(key).is_none(),
            "--{key} applies to --artifacts serving only"
        );
    }
    let net = net_arg(args)?;
    let images = args.get_usize("images", 60)?;
    let cap = args.get_usize("queue-cap", 2)?;
    let scale = args.get_f64("time-scale", 0.1)?;
    anyhow::ensure!(scale > 0.0, "--time-scale must be positive");
    anyhow::ensure!(images >= 1, "--images must be >= 1");
    let (hb, hs) = (cfg.platform.big.cores, cfg.platform.small.cores);

    let tm = TimeMatrix::measured(&cfg.platform, &net);
    let design = dse::explore_exact(&tm, hb, hs, replicas).with_context(|| {
        format!("no {replicas}-replica design fits on {hb}B+{hs}s")
    })?;
    println!(
        "simulated-time serving: {} on {} ({}B+{}s), {} replicas",
        net.name, cfg.platform.name, hb, hs, replicas
    );
    println!("fleet      : {}", design.partition_display());
    print_replicas(&design);

    let times = design.stage_times(&tm);
    let sim = pipeline_sim::simulate_replicated(&times, images, cap);

    // The real thread fleet: one sleep-stage per pipeline stage.
    let fleet = synthetic_fleet(&times, scale);
    let (_, report) = run_fleet(fleet, cap, 2 * replicas, 0..images);
    println!();
    print!("{}", report.render());
    println!(
        "predicted  : {:.2} imgs/s aggregate (DES, unscaled Eq. 10 times)",
        sim.throughput
    );
    println!(
        "wall-clock : {:.2} imgs/s at time-scale {scale} (~{:.2} imgs/s unscaled)",
        report.throughput(),
        report.throughput() * scale
    );
    Ok(())
}

/// Real PJRT serving over AOT artifacts (requires `--features pjrt`).
fn serve_artifacts(args: &Args, dir: &str, replicas: usize) -> Result<()> {
    let manifest = Manifest::load(std::path::Path::new(dir))?;
    let images = args.get_usize("images", 50)?;
    let batch = args.get_usize("batch", 1)?;
    let cap = args.get_usize("queue-cap", 2)?;
    let stages = args.get_usize("stages", 3)?;
    let seed = args.get_usize("seed", 7)? as u64;
    if args.has_flag("serial") {
        anyhow::ensure!(
            replicas == 1,
            "--serial serves on one thread; it cannot be combined with --replicas {replicas}"
        );
        let (_, report) = coordinator::serve_serial(&manifest, images, batch, seed)?;
        println!("serial (kernel-level analogue) on {}:", manifest.name);
        print!("{}", report.render());
    } else if replicas > 1 {
        let alloc = balance_by_macs(&manifest, stages);
        println!(
            "replicated serving on {}: {} replicas x {} stages: {}",
            manifest.name,
            replicas,
            alloc.active_stages(),
            alloc.display_1based()
        );
        let (_, report) =
            coordinator::serve_fleet(&manifest, &alloc, replicas, images, batch, cap, seed)?;
        print!("{}", report.render());
    } else {
        let alloc = balance_by_macs(&manifest, stages);
        println!(
            "pipelined serving on {} with {} stages: {}",
            manifest.name,
            alloc.active_stages(),
            alloc.display_1based()
        );
        let (_, report) =
            coordinator::serve_pipelined(&manifest, &alloc, images, batch, cap, seed)?;
        print!("{}", report.render());
    }
    Ok(())
}

/// Balance manifest layers into `k` contiguous stages by MAC count (the
/// host is a symmetric CPU, so MACs are the balancing proxy).
fn balance_by_macs(manifest: &Manifest, k: usize) -> dse::Allocation {
    let w = manifest.num_layers();
    let k = k.clamp(1, w);
    let total: usize = manifest.layers.iter().map(|l| l.macs).sum();
    let target = total as f64 / k as f64;
    let mut ranges = Vec::with_capacity(k);
    let mut lo = 0;
    let mut acc = 0.0;
    for (i, l) in manifest.layers.iter().enumerate() {
        acc += l.macs as f64;
        let stages_left = k - ranges.len();
        let layers_left = w - i - 1;
        if (acc >= target && stages_left > 1 && layers_left >= stages_left - 1)
            || layers_left + 1 == stages_left
        {
            ranges.push((lo, i + 1));
            lo = i + 1;
            acc = 0.0;
        }
    }
    if lo < w {
        ranges.push((lo, w));
    }
    dse::Allocation { ranges }
}
