//! Quantization cost model (paper §VII-D, Fig. 13).
//!
//! Models ARM-CL's QASYMM8 path: the integer GEMM core is faster, but the
//! de/re-quantization epilogue (see the L1 kernel
//! `python/compile/kernels/qgemm_pallas.py`, whose kernel/epilogue split
//! this mirrors) eats part of the gain. Calibrated to the paper's reported
//! deltas:
//!
//! * v18.05: conv layers 14% faster quantized, overall unchanged.
//! * v18.11: F32 20% faster than v18.05; quantized conv 24% faster than
//!   v18.11 F32, overall 19% faster.
//! * Pipe-it on v18.11+quant: 18% better than default => 31 imgs/s.

/// ARM-CL version factors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArmClVersion {
    V1805,
    V1811,
}

/// One Fig. 13 configuration result (times normalized: v18.05 F32 = 1.0).
#[derive(Debug, Clone)]
pub struct QuantPoint {
    pub version: ArmClVersion,
    pub quantized: bool,
    /// Convolution-portion execution time (normalized).
    pub conv_time: f64,
    /// Whole-network execution time (normalized).
    pub total_time: f64,
}

/// Conv share of MobileNet execution time (Fig. 6: ~0.95 for MobileNet,
/// but de/re-quant overhead applies to the conv portion).
const CONV_SHARE: f64 = 0.90;

/// Compute the four default-execution points of Fig. 13.
pub fn fig13_points() -> Vec<QuantPoint> {
    let mut out = Vec::new();
    for (version, ver_factor) in [(ArmClVersion::V1805, 1.0), (ArmClVersion::V1811, 0.80)] {
        let conv_f32 = CONV_SHARE * ver_factor;
        let rest = (1.0 - CONV_SHARE) * ver_factor;
        out.push(QuantPoint {
            version,
            quantized: false,
            conv_time: conv_f32,
            total_time: conv_f32 + rest,
        });
        // Quantized: integer core speedup on conv, but de/re-quantization
        // overhead offsets it — v18.05 nets zero overall gain (paper), the
        // reworked v18.11 keeps most of it.
        let (core_speedup, requant_overhead) = match version {
            ArmClVersion::V1805 => (0.86, 0.14), // -14% conv, +overhead elsewhere
            ArmClVersion::V1811 => (0.76, 0.012), // -24% conv, small overhead
        };
        let conv_q = conv_f32 * core_speedup;
        out.push(QuantPoint {
            version,
            quantized: true,
            conv_time: conv_q,
            total_time: conv_q + rest + requant_overhead * ver_factor,
        });
    }
    out
}

/// Pipe-it's effective per-frame latency on a given configuration: the
/// pipeline overlaps clusters, improving the default latency by the
/// measured Pipe-it gain (18% on v18.11 quantized — §VII-D).
pub fn pipeit_latency(point: &QuantPoint, pipeit_gain: f64) -> f64 {
    point.total_time / (1.0 + pipeit_gain)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn find(points: &[QuantPoint], v: ArmClVersion, q: bool) -> QuantPoint {
        points
            .iter()
            .find(|p| p.version == v && p.quantized == q)
            .unwrap_or_else(|| panic!("fig13 series missing {v:?} quantized={q}"))
            .clone()
    }

    #[test]
    fn v1805_quant_conv_faster_overall_flat() {
        let pts = fig13_points();
        let f32_ = find(&pts, ArmClVersion::V1805, false);
        let q8 = find(&pts, ArmClVersion::V1805, true);
        // Conv ~14% faster.
        assert!((1.0 - q8.conv_time / f32_.conv_time - 0.14).abs() < 0.01);
        // Overall within 1.5% of unchanged (paper: "remains unchanged").
        assert!((q8.total_time / f32_.total_time - 1.0).abs() < 0.015);
    }

    #[test]
    fn v1811_faster_and_quant_pays_off() {
        let pts = fig13_points();
        let f05 = find(&pts, ArmClVersion::V1805, false);
        let f11 = find(&pts, ArmClVersion::V1811, false);
        let q11 = find(&pts, ArmClVersion::V1811, true);
        // v18.11 F32 is 20% faster than v18.05 F32.
        assert!((1.0 - f11.total_time / f05.total_time - 0.20).abs() < 0.01);
        // Quantized conv 24% faster than v18.11 F32 conv.
        assert!((1.0 - q11.conv_time / f11.conv_time - 0.24).abs() < 0.01);
        // Overall ~19% faster.
        let overall = 1.0 - q11.total_time / f11.total_time;
        assert!((overall - 0.19).abs() < 0.03, "overall gain {overall:.3}");
    }

    #[test]
    fn pipeit_always_reduces_latency() {
        for p in fig13_points() {
            assert!(pipeit_latency(&p, 0.18) < p.total_time);
        }
    }
}
