//! Baselines the paper compares against: the default kernel-level split
//! (incl. cross-cluster HMP), published framework comparators, and the
//! QASYMM8 quantization cost model.

pub mod frameworks;
pub mod kernel_level;
pub mod quant;

pub use frameworks::{deepx_alexnet, fig14_series, fig4_row, Framework};
pub use kernel_level::{
    conv_time_share, core_sweep, layer_time_distribution, ratio_sweep, CoreSweepPoint,
};
pub use quant::{fig13_points, pipeit_latency, ArmClVersion, QuantPoint};
