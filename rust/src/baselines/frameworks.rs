//! Framework comparators (paper Fig. 4 and Fig. 14).
//!
//! The paper compares against published benchmark numbers for other
//! frameworks (NCNN, TVM, caffe-family), scaled across SoCs with
//! AI-Benchmark — these comparisons are *data*, not authors' code, so we
//! reproduce them as calibrated relative-efficiency factors against the
//! ARM-CL Big-cluster baseline (DESIGN.md §1 substitution table).

use crate::cnn::network::Network;
use crate::simulator::gemm;
use crate::simulator::platform::{CoreType, Platform};

/// A comparator framework with its throughput factor relative to ARM-CL
/// v18.05 on the Big cluster (factors derived from the paper's figures).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Framework {
    pub name: &'static str,
    /// Relative Big-cluster throughput vs ARM-CL v18.05 (= 1.0).
    pub factor: f64,
    /// Whether the GoogLeNet column exists (TVM's model zoo lacked it).
    pub supports_googlenet: bool,
}

/// Fig. 4 comparator set: ARM-CL ~ NCNN >> TVM (no NEON assembly).
pub const FIG4_FRAMEWORKS: [Framework; 3] = [
    Framework { name: "ARM-CL", factor: 1.0, supports_googlenet: true },
    // "The two frameworks present similar performance" (§II).
    Framework { name: "NCNN", factor: 0.95, supports_googlenet: true },
    // "outperform TVM implementation without NEON acceleration" (§II).
    Framework { name: "TVM", factor: 0.45, supports_googlenet: false },
];

/// Fig. 4: Big-cluster throughput per framework per network.
pub fn fig4_row(platform: &Platform, net: &Network) -> Vec<(String, Option<f64>)> {
    let base =
        1.0 / gemm::network_time(platform, &net.layers, CoreType::Big, platform.big.cores);
    FIG4_FRAMEWORKS
        .iter()
        .map(|f| {
            let tp = if net.name == "googlenet" && !f.supports_googlenet {
                None
            } else {
                Some(base * f.factor)
            };
            (f.name.to_string(), tp)
        })
        .collect()
}

/// Fig. 14 comparator set for MobileNet (scaled published numbers; the
/// paper's bars, normalized to its ARM-CL baseline of 17.4 imgs/s).
pub const FIG14_FRAMEWORKS: [Framework; 4] = [
    Framework { name: "caffe-android-lib*", factor: 0.35, supports_googlenet: true },
    Framework { name: "mini-caffe*", factor: 0.55, supports_googlenet: true },
    Framework { name: "NCNN", factor: 0.95, supports_googlenet: true },
    Framework { name: "TVM", factor: 0.45, supports_googlenet: true },
];

/// Fig. 14: MobileNet effective throughput of every framework plus Pipe-it
/// (and Pipe-it** = v18.11 + quantization, factor from Fig. 13).
pub fn fig14_series(
    platform: &Platform,
    mobilenet: &Network,
    pipeit_throughput: f64,
    pipeit_quant_factor: f64,
) -> Vec<(String, f64)> {
    let base = 1.0
        / gemm::network_time(platform, &mobilenet.layers, CoreType::Big, platform.big.cores);
    let mut out: Vec<(String, f64)> = FIG14_FRAMEWORKS
        .iter()
        .map(|f| (f.name.to_string(), base * f.factor))
        .collect();
    out.push(("Pipe-it".to_string(), pipeit_throughput));
    out.push(("Pipe-it**".to_string(), pipeit_throughput * pipeit_quant_factor));
    out
}

/// §VII-E DeepX comparison: DeepX on Snapdragon 800 reports 444 mJ per
/// AlexNet inference at a 500 ms latency budget => 2.25 imgs/J at 2 imgs/s.
pub struct DeepXPoint {
    pub throughput: f64,
    pub efficiency_imgs_per_j: f64,
}

pub fn deepx_alexnet() -> DeepXPoint {
    DeepXPoint { throughput: 2.0, efficiency_imgs_per_j: 1.0 / 0.444 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::zoo;

    #[test]
    fn fig4_ordering() {
        let p = Platform::hikey970();
        for net in zoo::all_networks() {
            let row = fig4_row(&p, &net);
            let get = |n: &str| {
                row.iter()
                    .find(|(name, _)| name == n)
                    .unwrap_or_else(|| panic!("fig4 row is missing the {n:?} series"))
                    .1
            };
            let armcl = get("ARM-CL").expect("ARM-CL baseline has no throughput");
            if let Some(ncnn) = get("NCNN") {
                assert!((ncnn / armcl - 0.95).abs() < 1e-9);
            }
            match get("TVM") {
                Some(tvm) => assert!(tvm < armcl * 0.5),
                None => assert_eq!(net.name, "googlenet"),
            }
        }
    }

    #[test]
    fn fig14_pipeit_wins() {
        let p = Platform::hikey970();
        let net = zoo::mobilenet();
        let series = fig14_series(&p, &net, 29.0, 1.18);
        let pipeit = series
            .iter()
            .find(|(n, _)| n == "Pipe-it")
            .expect("fig14 series missing Pipe-it")
            .1;
        let best_other = series
            .iter()
            .filter(|(n, _)| !n.starts_with("Pipe-it"))
            .map(|(_, tp)| *tp)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(pipeit > best_other);
        let quant = series
            .iter()
            .find(|(n, _)| n == "Pipe-it**")
            .expect("fig14 series missing Pipe-it**")
            .1;
        assert!(quant > pipeit);
    }

    #[test]
    fn deepx_numbers() {
        let d = deepx_alexnet();
        assert!((d.efficiency_imgs_per_j - 2.25).abs() < 0.01);
    }
}
