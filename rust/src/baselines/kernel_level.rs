//! Kernel-level splitting baselines (paper §III-A, Figures 3 & 5).
//!
//! The default ARM-CL strategy: one image at a time, every kernel split
//! across all engaged cores — intra-cluster first, then Heterogeneous
//! Multi-Processing (HMP) across clusters, which is where throughput
//! collapses (CCI conflict misses).

use crate::cnn::network::Network;
use crate::simulator::gemm;
use crate::simulator::platform::{CoreType, Platform};

/// One point of the Fig. 3 series.
#[derive(Debug, Clone)]
pub struct CoreSweepPoint {
    pub label: String,
    pub big: usize,
    pub small: usize,
    pub throughput: f64,
}

/// Fig. 3: throughput as cores are added — 1B..4B, then 4B+1s..4B+4s.
pub fn core_sweep(platform: &Platform, net: &Network) -> Vec<CoreSweepPoint> {
    let mut out = Vec::new();
    for b in 1..=platform.big.cores {
        let t = gemm::network_time(platform, &net.layers, CoreType::Big, b);
        out.push(CoreSweepPoint {
            label: format!("{b}B"),
            big: b,
            small: 0,
            throughput: 1.0 / t,
        });
    }
    for s in 1..=platform.small.cores {
        let t = gemm::network_time_hmp(platform, &net.layers, platform.big.cores, s);
        out.push(CoreSweepPoint {
            label: format!("{}B{}s", platform.big.cores, s),
            big: platform.big.cores,
            small: s,
            throughput: 1.0 / t,
        });
    }
    out
}

/// Fig. 5: exhaustive disproportionate Big/Small workload-ratio sweep,
/// throughput normalized to Big-cluster-only execution.
pub fn ratio_sweep(platform: &Platform, net: &Network, steps: usize) -> Vec<(f64, f64)> {
    let t_big = gemm::network_time(platform, &net.layers, CoreType::Big, platform.big.cores);
    (0..=steps)
        .map(|i| {
            let r = i as f64 / steps as f64;
            let t: f64 = net
                .layers
                .iter()
                .map(|l| {
                    gemm::layer_time_hmp_ratio(
                        platform,
                        l,
                        platform.big.cores,
                        platform.small.cores,
                        r,
                    )
                })
                .sum();
            (r, t_big / t)
        })
        .collect()
}

/// Fig. 6: fraction of total forward-pass time spent in convolutional
/// (non-FC) layers, on the Big cluster.
pub fn conv_time_share(platform: &Platform, net: &Network) -> f64 {
    use crate::cnn::layer::LayerKind;
    let h = platform.big.cores;
    let total: f64 = gemm::network_time(platform, &net.layers, CoreType::Big, h);
    let conv: f64 = net
        .layers
        .iter()
        .filter(|l| l.kind != LayerKind::Fc)
        .map(|l| gemm::layer_time(platform, l, CoreType::Big, h))
        .sum();
    conv / total
}

/// Fig. 7: per-layer share of total convolution time (Big cluster, all
/// cores), in layer order.
pub fn layer_time_distribution(platform: &Platform, net: &Network) -> Vec<f64> {
    let h = platform.big.cores;
    let times: Vec<f64> = net
        .layers
        .iter()
        .map(|l| gemm::layer_time(platform, l, CoreType::Big, h))
        .collect();
    let total: f64 = times.iter().sum();
    times.into_iter().map(|t| t / total).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::zoo;

    #[test]
    fn fig3_shape_rise_drop_recover() {
        let p = Platform::hikey970();
        for net in zoo::all_networks() {
            let sweep = core_sweep(&p, &net);
            assert_eq!(sweep.len(), 8);
            // Rising through Big cores.
            for w in sweep[..4].windows(2) {
                assert!(w[1].throughput > w[0].throughput, "{}", net.name);
            }
            // Sharp drop at 4B+1s.
            assert!(sweep[4].throughput < sweep[3].throughput, "{}", net.name);
            // Recovery with more Small cores but never beating 4B.
            assert!(sweep[7].throughput > sweep[4].throughput, "{}", net.name);
            assert!(sweep[7].throughput <= sweep[3].throughput * 1.01, "{}", net.name);
        }
    }

    #[test]
    fn fig5_big_only_is_best() {
        let p = Platform::hikey970();
        for net in zoo::all_networks() {
            let sweep = ratio_sweep(&p, &net, 20);
            let best = sweep.iter().map(|(_, tp)| *tp).fold(f64::NEG_INFINITY, f64::max);
            let at_one = sweep.last().expect("fig5 ratio sweep is empty").1;
            assert!((at_one - 1.0).abs() < 1e-9);
            assert!(best <= 1.03, "{}: ratio sweep best {best:.3} beats Big-only", net.name);
        }
    }

    #[test]
    fn fig6_conv_dominates_except_alexnet() {
        let p = Platform::hikey970();
        let share_alex = conv_time_share(&p, &zoo::alexnet());
        assert!(share_alex < 0.65, "AlexNet conv share {share_alex:.2} should be lowest");
        for name in ["googlenet", "mobilenet", "resnet50", "squeezenet"] {
            let share = conv_time_share(
                &p,
                &zoo::by_name(name)
                    .unwrap_or_else(|| panic!("zoo is missing network {name:?}")),
            );
            assert!(share > 0.85, "{name}: conv share {share:.2}");
            assert!(share > share_alex);
        }
    }

    #[test]
    fn fig7_front_heavier_than_back() {
        // Fig. 7 plots *convolutional* layer time over depth: generally
        // decreasing. Compare first vs last third of conv (non-FC) layers;
        // MobileNet is intentionally compute-uniform by design, so it only
        // gets a no-strong-inversion check.
        use crate::cnn::layer::LayerKind;
        let p = Platform::hikey970();
        for net in zoo::all_networks() {
            let dist = layer_time_distribution(&p, &net);
            assert!((dist.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            let conv: Vec<f64> = net
                .layers
                .iter()
                .zip(&dist)
                .filter(|(l, _)| l.kind != LayerKind::Fc)
                .map(|(_, d)| *d)
                .collect();
            let w = conv.len();
            let front: f64 = conv[..w / 3].iter().sum();
            let back: f64 = conv[w - w / 3..].iter().sum();
            // MobileNet and ResNet50 are compute-uniform over depth by
            // design (channel doubling offsets spatial halving), so they
            // only get a no-strong-inversion check.
            let slack = match net.name.as_str() {
                "mobilenet" => 0.7,
                // fire8/9 (512-ch at 26x26) and conv10 are genuinely heavy
                // in SqueezeNet v1.0's arithmetic.
                "resnet50" | "squeezenet" => 0.8,
                _ => 1.0,
            };
            assert!(
                front > back * slack,
                "{}: front third {front:.2} vs back third {back:.2}",
                net.name
            );
        }
    }
}
