//! The schema-versioned benchmark artifact: [`BenchReport`] — what
//! `pipeit bench --out BENCH_<n>.json` writes and `pipeit bench --compare`
//! reads. One [`ScenarioResult`] per (scenario, backend) entry, carrying
//! the raw metric samples plus the robust statistics the regression gate
//! classifies on ([`SampleStats`]): median after MAD outlier rejection and
//! a seeded bootstrap confidence interval of the median.
//!
//! The JSON schema is documented in `DESIGN.md` §11; as with
//! [`crate::api::Plan`], a report saved with [`BenchReport::save`] reloads
//! losslessly with [`BenchReport::load`].

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::stats;

/// Bench schema version written by [`BenchReport::save`] and required by
/// [`BenchReport::load`].
pub const BENCH_VERSION: usize = 1;

/// Robust summary of one scenario's metric samples.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleStats {
    /// Samples kept after MAD outlier rejection.
    pub n: usize,
    /// Samples dropped by the rejection pass.
    pub rejected: usize,
    /// Median of the kept samples — the value the regression gate compares.
    pub median: f64,
    pub mean: f64,
    /// Raw (unscaled) median absolute deviation of the kept samples.
    pub mad: f64,
    /// Bootstrap confidence interval of the median (contains `median`).
    pub ci_lo: f64,
    pub ci_hi: f64,
}

impl SampleStats {
    /// Reject outliers ([`stats::mad_filter`] at `mad_k`), then summarize
    /// with a `confidence`-level bootstrap CI of the median drawn from the
    /// deterministic stream of `seed` — same samples + same seed give
    /// bit-identical stats, which is what makes the CI determinism gate
    /// exact.
    pub fn from_samples(
        samples: &[f64],
        mad_k: f64,
        confidence: f64,
        resamples: usize,
        seed: u64,
    ) -> SampleStats {
        let kept = stats::mad_filter(samples, mad_k);
        let (ci_lo, ci_hi) = stats::bootstrap_ci_median(&kept, confidence, resamples, seed);
        SampleStats {
            n: kept.len(),
            rejected: samples.len() - kept.len(),
            median: stats::median(&kept),
            mean: stats::mean(&kept),
            mad: stats::mad(&kept),
            ci_lo,
            ci_hi,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("median", Json::num(self.median)),
            ("mean", Json::num(self.mean)),
            ("mad", Json::num(self.mad)),
            ("ci_lo", Json::num(self.ci_lo)),
            ("ci_hi", Json::num(self.ci_hi)),
        ])
    }

    fn from_json(j: &Json) -> Result<SampleStats> {
        Ok(SampleStats {
            n: j.req("n")?.as_usize().context("stats n")?,
            rejected: j.req("rejected")?.as_usize().context("stats rejected")?,
            median: j.req("median")?.as_f64().context("stats median")?,
            mean: j.req("mean")?.as_f64().context("stats mean")?,
            mad: j.req("mad")?.as_f64().context("stats mad")?,
            ci_lo: j.req("ci_lo")?.as_f64().context("stats ci_lo")?,
            ci_hi: j.req("ci_hi")?.as_f64().context("stats ci_hi")?,
        })
    }
}

/// One (scenario, backend) entry of a bench run.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioResult {
    /// Scenario name from the registry (`pipelined/alexnet`).
    pub name: String,
    /// Serving mode (`serial`, `pipelined`, `replicated`, `adaptive`,
    /// `multi-tenant`) or `micro` for host micro-benchmarks.
    pub mode: String,
    /// Which twin produced the samples: `des`, `wall`, or `host`.
    pub backend: String,
    /// Metric unit: `imgs/s` for serving scenarios, `s` for micro benches.
    pub unit: String,
    /// Regression direction: true when a smaller metric is a regression
    /// (throughput); false for time-like metrics.
    pub higher_is_better: bool,
    /// Raw metric samples in repetition order, BEFORE outlier rejection
    /// (micro benches store stats only — their sample counts are large).
    pub samples: Vec<f64>,
    pub stats: SampleStats,
    /// Host seconds spent producing this entry (warmup + all repetitions).
    /// Informational only: never compared, and not deterministic.
    pub host_s: f64,
    /// Observability registry snapshot from one recorded repetition
    /// (DESIGN.md §13) — the runner records the last DES repetition so
    /// perf artifacts carry per-stage occupancy and latency histograms.
    /// `None` for wall/host entries and pre-observability artifacts.
    pub metrics: Option<crate::obs::MetricsSnapshot>,
}

impl ScenarioResult {
    /// The identity `--compare` matches entries by.
    pub fn key(&self) -> String {
        format!("{}/{}", self.backend, self.name)
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("mode", Json::str(&self.mode)),
            ("backend", Json::str(&self.backend)),
            ("unit", Json::str(&self.unit)),
            ("higher_is_better", Json::Bool(self.higher_is_better)),
            ("samples", Json::Arr(self.samples.iter().map(|&x| Json::num(x)).collect())),
            ("stats", self.stats.to_json()),
            ("host_s", Json::num(self.host_s)),
        ];
        if let Some(m) = &self.metrics {
            fields.push(("metrics", m.to_json()));
        }
        Json::obj(fields)
    }

    fn from_json(j: &Json) -> Result<ScenarioResult> {
        Ok(ScenarioResult {
            name: j.req("name")?.as_str().context("scenario name")?.to_string(),
            mode: j.req("mode")?.as_str().context("scenario mode")?.to_string(),
            backend: j.req("backend")?.as_str().context("scenario backend")?.to_string(),
            unit: j.req("unit")?.as_str().context("scenario unit")?.to_string(),
            higher_is_better: j
                .req("higher_is_better")?
                .as_bool()
                .context("higher_is_better")?,
            samples: j.req("samples")?.f64_arr().context("samples array")?,
            stats: SampleStats::from_json(j.req("stats")?)?,
            host_s: j.req("host_s")?.as_f64().context("host_s")?,
            metrics: match j.get("metrics") {
                None => None,
                Some(m) => Some(
                    crate::obs::MetricsSnapshot::from_json(m).context("scenario metrics")?,
                ),
            },
        })
    }
}

/// A full bench run: the machine-readable perf artifact
/// (`BENCH_<n>.json`). Rendered for humans by
/// [`crate::reports::render_bench`].
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    /// Suite the run executed (`quick`, `full`, or a bench target's name).
    pub suite: String,
    /// Base seed every scenario's repetition seeds derive from.
    pub seed: u64,
    /// Warmup runs discarded per scenario.
    pub warmup: usize,
    /// Measured repetitions per scenario.
    pub reps: usize,
    /// Which 0-based repetition the scenario `metrics` snapshots describe
    /// (the runner records the LAST DES repetition; DESIGN.md §13/§14).
    /// `None` for runs that record nothing (host micro-bench reports,
    /// pre-observability artifacts) — optional in the JSON, so version-1
    /// artifacts from before this field still load.
    pub recorded_rep: Option<usize>,
    pub scenarios: Vec<ScenarioResult>,
}

impl BenchReport {
    /// Look up an entry by its `backend/name` key.
    pub fn find(&self, key: &str) -> Option<&ScenarioResult> {
        self.scenarios.iter().find(|s| s.key() == key)
    }

    /// Distinct serving modes covered by the run.
    pub fn modes(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for s in &self.scenarios {
            if !out.contains(&s.mode.as_str()) {
                out.push(&s.mode);
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("version", Json::num(BENCH_VERSION as f64)),
            ("suite", Json::str(&self.suite)),
            ("seed", Json::num(self.seed as f64)),
            ("warmup", Json::num(self.warmup as f64)),
            ("reps", Json::num(self.reps as f64)),
            (
                "scenarios",
                Json::Arr(self.scenarios.iter().map(ScenarioResult::to_json).collect()),
            ),
        ];
        if let Some(r) = self.recorded_rep {
            fields.push(("recorded_rep", Json::num(r as f64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<BenchReport> {
        let version = j.req("version")?.as_usize().context("version")?;
        anyhow::ensure!(
            version == BENCH_VERSION,
            "bench schema version {version} is not supported (field \"version\"; \
             this build reads version {BENCH_VERSION})"
        );
        let mut scenarios = Vec::new();
        for (i, sj) in j.req("scenarios")?.as_arr().context("scenarios array")?.iter().enumerate()
        {
            scenarios.push(
                ScenarioResult::from_json(sj).with_context(|| format!("scenario {i}"))?,
            );
        }
        Ok(BenchReport {
            suite: j.req("suite")?.as_str().context("suite")?.to_string(),
            seed: j.req("seed")?.as_f64().context("seed")?.max(0.0) as u64,
            warmup: j.req("warmup")?.as_usize().context("warmup")?,
            reps: j.req("reps")?.as_usize().context("reps")?,
            recorded_rep: match j.get("recorded_rep") {
                None => None,
                Some(v) => Some(v.as_usize().context("recorded_rep")?),
            },
            scenarios,
        })
    }

    /// Write the artifact (`BENCH_<n>.json`).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load an artifact saved by [`BenchReport::save`].
    pub fn load(path: &Path) -> Result<BenchReport> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        BenchReport::from_json(&j)
            .with_context(|| format!("parsing bench report {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> BenchReport {
        let samples = vec![10.0, 10.2, 9.8, 10.1, 60.0];
        BenchReport {
            suite: "quick".into(),
            seed: 7,
            warmup: 1,
            reps: 5,
            recorded_rep: Some(4),
            scenarios: vec![ScenarioResult {
                name: "pipelined/alexnet".into(),
                mode: "pipelined".into(),
                backend: "des".into(),
                unit: "imgs/s".into(),
                higher_is_better: true,
                samples: samples.clone(),
                stats: SampleStats::from_samples(&samples, 3.5, 0.95, 200, 99),
                host_s: 0.25,
                metrics: None,
            }],
        }
    }

    #[test]
    fn stats_reject_the_outlier_and_bracket_the_median() {
        let s = SampleStats::from_samples(&[10.0, 10.2, 9.8, 10.1, 60.0], 3.5, 0.95, 200, 1);
        assert_eq!(s.n, 4);
        assert_eq!(s.rejected, 1);
        assert!((s.median - 10.05).abs() < 1e-9, "median {}", s.median);
        assert!(s.ci_lo <= s.median && s.median <= s.ci_hi);
    }

    #[test]
    fn stats_are_deterministic_given_seed() {
        let xs = [5.0, 5.1, 4.9, 5.2, 4.8, 5.05];
        let a = SampleStats::from_samples(&xs, 3.5, 0.95, 300, 17);
        let b = SampleStats::from_samples(&xs, 3.5, 0.95, 300, 17);
        assert_eq!(a, b);
    }

    #[test]
    fn report_json_roundtrips_losslessly() {
        let r = sample_report();
        let text = r.to_json().to_string();
        let j = Json::parse(&text).expect("bench JSON reparses");
        assert_eq!(BenchReport::from_json(&j).expect("deserializes"), r);
    }

    #[test]
    fn load_rejects_wrong_version_naming_the_field() {
        let mut j = sample_report().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("version".to_string(), Json::num(99.0));
        }
        let err = BenchReport::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("\"version\""), "{err}");
        assert!(err.contains("99"), "{err}");
    }

    /// ISSUE 9 satellite: `recorded_rep` is schema-compatible — absent
    /// from pre-observability artifacts (loads back as `None`), present
    /// and lossless when set.
    #[test]
    fn recorded_rep_is_optional_and_loads_back() {
        let r = sample_report();
        let j = r.to_json();
        assert_eq!(j.req("recorded_rep").unwrap().as_usize(), Some(4));
        assert_eq!(BenchReport::from_json(&j).unwrap().recorded_rep, Some(4));
        // A version-1 artifact written before the field existed.
        let mut old = j.clone();
        if let Json::Obj(m) = &mut old {
            m.remove("recorded_rep");
        }
        let loaded = BenchReport::from_json(&old).expect("old artifact loads");
        assert_eq!(loaded.recorded_rep, None);
    }

    #[test]
    fn find_uses_backend_qualified_keys() {
        let r = sample_report();
        assert!(r.find("des/pipelined/alexnet").is_some());
        assert!(r.find("wall/pipelined/alexnet").is_none());
        assert_eq!(r.modes(), vec!["pipelined"]);
    }
}
