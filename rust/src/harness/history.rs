//! Longitudinal bench trajectory: a directory of `BENCH_*.json`
//! artifacts read as one time series instead of pairwise compares.
//!
//! `pipeit bench --compare` answers "did this change regress anything";
//! this module answers "where has each scenario been heading" — the
//! ROADMAP's perf-trajectory item. [`BenchHistory::load_dir`] scans a
//! directory for `BENCH_*.json`, orders the artifacts (numeric stems
//! ascending first — `BENCH_0`, `BENCH_1`, `BENCH_10` — then the rest
//! lexicographically), and exposes the per-scenario median trajectory
//! two ways (DESIGN.md §14):
//!
//! * a rendered table (`reports::render_history`): one row per scenario,
//!   one column per artifact, plus the first→last relative delta;
//! * [`BenchHistory::dat`]: whitespace-separated gnuplot data (one row
//!   per artifact, one column per scenario, `nan` for scenarios an
//!   artifact does not carry) — `plot "history.dat" using 0:2 with
//!   lines` plots the first scenario's trajectory directly.
//!
//! Scenarios are keyed `backend/name` — the same identity
//! `harness::compare` uses, so a row here matches a verdict line there.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::report::{BenchReport, ScenarioResult};

/// One artifact in the trajectory: its label (file stem with the
/// `BENCH_` prefix stripped) and the loaded report.
#[derive(Debug, Clone)]
pub struct HistoryEntry {
    pub label: String,
    pub report: BenchReport,
}

/// An ordered sequence of bench artifacts (module docs).
#[derive(Debug, Clone)]
pub struct BenchHistory {
    pub entries: Vec<HistoryEntry>,
}

/// The scenario identity used across artifacts: `backend/name` (the
/// same key `harness::compare` reports added/removed scenarios under).
pub fn scenario_key(s: &ScenarioResult) -> String {
    format!("{}/{}", s.backend, s.name)
}

/// Artifact ordering: fully-numeric labels ascending first (the
/// `BENCH_0`, `BENCH_1`, … convention), then the rest lexicographically.
fn label_key(label: &str) -> (u8, u64, String) {
    match label.parse::<u64>() {
        Ok(n) => (0, n, label.to_string()),
        Err(_) => (1, 0, label.to_string()),
    }
}

impl BenchHistory {
    /// Wrap pre-loaded entries in the given order (tests, synthetic
    /// trajectories).
    pub fn from_entries(entries: Vec<HistoryEntry>) -> BenchHistory {
        BenchHistory { entries }
    }

    /// Scan `dir` for `BENCH_*.json`, order the artifacts, load each.
    pub fn load_dir(dir: &Path) -> Result<BenchHistory> {
        let mut found = Vec::new();
        let listing = std::fs::read_dir(dir)
            .with_context(|| format!("reading bench-history dir {}", dir.display()))?;
        for entry in listing {
            let entry = entry.with_context(|| format!("listing {}", dir.display()))?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if let Some(stem) =
                name.strip_prefix("BENCH_").and_then(|s| s.strip_suffix(".json"))
            {
                found.push((stem.to_string(), entry.path()));
            }
        }
        ensure!(
            !found.is_empty(),
            "no BENCH_*.json artifacts in {} (run `pipeit bench --out \
             BENCH_0.json` to start a trajectory)",
            dir.display()
        );
        found.sort_by(|a, b| label_key(&a.0).cmp(&label_key(&b.0)));
        let entries = found
            .into_iter()
            .map(|(label, path)| {
                let report = BenchReport::load(&path)
                    .with_context(|| format!("loading {}", path.display()))?;
                Ok(HistoryEntry { label, report })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(BenchHistory { entries })
    }

    /// Scenario keys in first-seen order across the entries, so rows are
    /// stable as scenarios come and go over the trajectory.
    pub fn keys(&self) -> Vec<String> {
        let mut keys: Vec<String> = Vec::new();
        for e in &self.entries {
            for s in &e.report.scenarios {
                let k = scenario_key(s);
                if !keys.contains(&k) {
                    keys.push(k);
                }
            }
        }
        keys
    }

    /// The scenario row behind `key` in entry `idx`, if that artifact
    /// carries it.
    pub fn scenario(&self, idx: usize, key: &str) -> Option<&ScenarioResult> {
        self.entries
            .get(idx)?
            .report
            .scenarios
            .iter()
            .find(|s| scenario_key(s) == key)
    }

    /// `key`'s median in entry `idx`, if present.
    pub fn median(&self, idx: usize, key: &str) -> Option<f64> {
        self.scenario(idx, key).map(|s| s.stats.median)
    }

    /// Gnuplot data export (module docs): a `# label key…` header, then
    /// one row per artifact with each scenario's median (`nan` when the
    /// artifact lacks the scenario).
    pub fn dat(&self) -> String {
        let keys = self.keys();
        let mut out = String::from("# label");
        for k in &keys {
            out.push(' ');
            out.push_str(k);
        }
        out.push('\n');
        for (i, e) in self.entries.iter().enumerate() {
            out.push_str(&e.label);
            for k in &keys {
                match self.median(i, k) {
                    Some(m) => out.push_str(&format!(" {m}")),
                    None => out.push_str(" nan"),
                }
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::report::SampleStats;

    fn entry(name: &str, backend: &str, median: f64) -> ScenarioResult {
        ScenarioResult {
            name: name.into(),
            mode: "pipelined".into(),
            backend: backend.into(),
            unit: "imgs/s".into(),
            higher_is_better: true,
            samples: vec![median; 3],
            stats: SampleStats {
                n: 3,
                rejected: 0,
                median,
                mean: median,
                mad: 0.0,
                ci_lo: median,
                ci_hi: median,
            },
            host_s: 0.1,
            metrics: None,
        }
    }

    fn report(entries: Vec<ScenarioResult>) -> BenchReport {
        BenchReport {
            suite: "quick".into(),
            seed: 7,
            warmup: 0,
            reps: 3,
            recorded_rep: None,
            scenarios: entries,
        }
    }

    fn two_point_history() -> BenchHistory {
        BenchHistory::from_entries(vec![
            HistoryEntry {
                label: "0".into(),
                report: report(vec![
                    entry("pipelined/alexnet", "des", 16.0),
                    entry("serial/alexnet", "des", 4.5),
                ]),
            },
            HistoryEntry {
                label: "1".into(),
                report: report(vec![
                    entry("pipelined/alexnet", "des", 17.6),
                    // serial/alexnet dropped, a new scenario appears.
                    entry("replicated/alexnet", "des", 21.0),
                ]),
            },
        ])
    }

    #[test]
    fn keys_are_first_seen_order_across_entries() {
        assert_eq!(
            two_point_history().keys(),
            vec![
                "des/pipelined/alexnet".to_string(),
                "des/serial/alexnet".to_string(),
                "des/replicated/alexnet".to_string(),
            ]
        );
    }

    #[test]
    fn dat_rows_per_artifact_with_nan_holes() {
        let expected = "\
# label des/pipelined/alexnet des/serial/alexnet des/replicated/alexnet
0 16 4.5 nan
1 17.6 nan 21
";
        assert_eq!(two_point_history().dat(), expected);
    }

    #[test]
    fn labels_order_numerically_then_lexicographically() {
        let mut labels = vec!["10", "ci", "2", "0", "ci_rerun"];
        labels.sort_by(|a, b| label_key(a).cmp(&label_key(b)));
        assert_eq!(labels, vec!["0", "2", "10", "ci", "ci_rerun"]);
    }

    #[test]
    fn load_dir_scans_orders_and_rejects_empty() {
        let dir = std::env::temp_dir()
            .join(format!("pipeit_history_scan_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("temp dir");
        let empty = BenchHistory::load_dir(&dir).unwrap_err().to_string();
        assert!(empty.contains("no BENCH_*.json"), "unhelpful error: {empty}");
        let h = two_point_history();
        // Write out of order; names that don't match the pattern are skipped.
        for (e, file) in h.entries.iter().zip(["BENCH_10.json", "BENCH_2.json"]) {
            std::fs::write(dir.join(file), format!("{}\n", e.report.to_json()))
                .expect("artifact written");
        }
        std::fs::write(dir.join("notes.txt"), "ignored").expect("written");
        let loaded = BenchHistory::load_dir(&dir).expect("loads");
        std::fs::remove_dir_all(&dir).ok();
        let labels: Vec<&str> =
            loaded.entries.iter().map(|e| e.label.as_str()).collect();
        assert_eq!(labels, vec!["2", "10"]);
        assert_eq!(loaded.median(1, "des/pipelined/alexnet"), Some(17.6));
    }
}
