//! Benchmark orchestration (DESIGN.md §11): a scenario registry spanning
//! every serving mode, a runner with warmup/repetition control and robust
//! statistics, a schema-versioned perf artifact, and a CI-overlap
//! regression gate — the machinery behind `pipeit bench`.
//!
//! Pipe-it's value claim is quantitative (the paper's +39% throughput
//! headline), so the repo must be able to measure itself and notice when a
//! refactor costs performance. The pieces, in data-flow order:
//!
//! * [`registry`] / [`Suite`] — named scenarios covering serial,
//!   pipelined, replicated-fleet, adaptive-under-throttle, and
//!   multi-tenant serving, each runnable on both execution twins
//!   ([`Backend::Des`] and [`Backend::Wall`]). The differential
//!   conformance suite (`tests/des_wallclock_diff.rs`) pins the twins to
//!   each other per scenario.
//! * [`run_suite`] / [`RunnerOptions`] — warmup + repetitions per entry,
//!   per-repetition derived seeds, then median / MAD outlier rejection /
//!   seeded bootstrap CI ([`SampleStats`], in the spirit of robust
//!   benchmarking harnesses like `bencher`).
//! * [`BenchReport`] — the schema-versioned `BENCH_<n>.json` artifact
//!   ([`BENCH_VERSION`]), rendered by [`crate::reports::render_bench`].
//! * [`compare()`] — classify each scenario improved / regressed / unchanged
//!   by CI overlap (never point deltas); `pipeit bench --compare` exits
//!   non-zero on any regression, and CI's determinism gate asserts two
//!   same-seed quick runs compare as all-unchanged.
//! * [`HostBench`] — the same statistics for `cargo bench` micro-timings;
//!   the `benches/*.rs` targets are thin wrappers over it.
//! * [`BenchHistory`] — the longitudinal view (`pipeit bench history`):
//!   a directory of `BENCH_*.json` artifacts read as one per-scenario
//!   trajectory, rendered as a table ([`crate::reports::render_history`])
//!   or exported as gnuplot `.dat` data.
//!
//! # Example
//!
//! ```
//! use pipeit::harness::{compare, run_suite, RunnerOptions, Suite};
//!
//! let opts = RunnerOptions { reps: 1, warmup: 0, ..Default::default() };
//! let report = run_suite(Suite::Quick, &opts).unwrap();
//! assert!(report.scenarios.len() >= 8);
//! // A report never regresses against itself — the determinism gate's
//! // two same-seed runs are bit-identical, so neither does a re-run.
//! assert!(!compare(&report, &report, 0.01).has_regressions());
//! ```

pub mod compare;
pub mod history;
pub mod report;
pub mod runner;
pub mod scenario;

pub use compare::{
    compare, BenchComparison, ScenarioDiff, Verdict, DEFAULT_MIN_REL_DELTA,
};
pub use history::{scenario_key, BenchHistory, HistoryEntry};
pub use report::{BenchReport, SampleStats, ScenarioResult, BENCH_VERSION};
pub use runner::{black_box, run_suite, save_if_requested, HostBench, RunnerOptions};
pub use scenario::{registry, suite_entries, Backend, Scenario, Suite, SuiteEntry};
