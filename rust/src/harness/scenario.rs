//! The scenario registry: every serving mode shipped so far, each runnable
//! on BOTH execution twins — the discrete-event simulator and the
//! wall-clock thread executor — through one [`Scenario::run`] entry point.
//!
//! A scenario is a *workload*, not a backend: `pipelined/alexnet` names the
//! paper's single-pipeline design serving a saturated stream, and the
//! [`Backend`] chooses whether the metric comes from the DES recurrence or
//! from real threads sleeping the (time-scaled) Eq. 10 service times. This
//! pairing is what the differential conformance suite
//! (`tests/des_wallclock_diff.rs`) keeps honest: for every scenario the two
//! twins must agree within the scenario's declared [`Scenario::tolerance`],
//! and neither may exceed its Eq. 12 capacity ([`Scenario::capacity`]).
//!
//! Suites pick which (scenario, backend) entries a bench run executes:
//! [`Suite::Quick`] is DES-only — pure deterministic computation, the CI
//! determinism gate — while [`Suite::Full`] adds every wall-clock twin.

use anyhow::{Context, Result};

use crate::adapt::{self, AdaptOptions, ClusterThrottle};
use crate::api::{DeployOptions, Plan, PlanSpec, Strategy};
use crate::cluster::{
    BoardSpec, ClusterPlan, ClusterServeOptions, ClusterSpec, DispatchPolicy,
};
use crate::cnn::zoo;
use crate::config::Config;
use crate::perfmodel::TimeMatrix;
use crate::simulator::platform::CoreType;
use crate::tenancy::{MultiPlan, MultiServeOptions, TenantSpec};

/// Which execution twin produces the metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Discrete-event simulation: exact, threadless, bit-deterministic.
    Des,
    /// The real thread executor over synthetic sleep stages, normalized by
    /// the scenario's time scale back to model seconds.
    Wall,
}

impl Backend {
    /// Stable key used in bench artifacts (`des`, `wall`).
    pub fn key(self) -> &'static str {
        match self {
            Backend::Des => "des",
            Backend::Wall => "wall",
        }
    }
}

/// What the scenario actually runs (private: the registry is the API).
#[derive(Debug, Clone)]
enum Spec {
    /// A compiled [`Plan`] serving a saturated stream (serial, pipelined,
    /// or replicated — the strategy decides).
    Plan { net: &'static str, strategy: Strategy },
    /// Closed-loop adaptive serving under a scripted big-cluster throttle
    /// ([`adapt::simulate_adaptive`] / [`adapt::deploy_adaptive`]).
    Adaptive { net: &'static str, throttle_at: f64, factor: f64 },
    /// Multi-tenant co-serving of seeded Poisson streams through the joint
    /// plan's per-tenant fleets; the metric is the weighted served rate.
    Multi { tenants: &'static [(&'static str, f64)], max_replicas: usize },
    /// Cluster-scale serving: a fleet of heterogeneous boards behind one
    /// front-door router, offered `saturation ×` the fleet's Σ Eq. 12
    /// capacity; the metric is the aggregate served rate.
    Cluster {
        boards: &'static [(usize, usize)],
        net: &'static str,
        saturation: f64,
        policy: DispatchPolicy,
    },
}

/// One registry entry: a named workload runnable on either backend.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Registry name (`mode/network[...]`), unique across the registry.
    pub name: String,
    /// Serving mode: `serial`, `pipelined`, `replicated`, `adaptive`,
    /// `multi-tenant`, or `cluster`.
    pub mode: &'static str,
    /// Stream length (items per run; arrivals per tenant for multi-tenant).
    pub images: usize,
    /// Inter-stage queue capacity.
    pub queue_cap: usize,
    /// Wall twin time scale: threads sleep `stage_time * time_scale`.
    pub time_scale: f64,
    /// Declared relative tolerance for DES-vs-wall agreement — the bound
    /// the differential conformance suite enforces per scenario.
    pub tolerance: f64,
    /// DES-twin-only scenario: excluded from wall-clock suites and the
    /// DES-vs-wall conformance sweep. Used by throughput-stress entries
    /// (e.g. `multi/hot-2x500k`, 1M arrivals) whose wall twin would sleep
    /// for hours; `tolerance` is still declared for uniformity but nothing
    /// enforces it.
    pub des_only: bool,
    spec: Spec,
}

impl Scenario {
    /// Run the scenario on `backend` and return its throughput metric in
    /// model imgs/s (weighted imgs/s for multi-tenant) — wall-clock results
    /// are normalized by the time scale so both twins are comparable.
    ///
    /// `seed` drives stochastic inputs (arrival streams); scenarios without
    /// stochastic inputs ignore it. DES runs are bit-deterministic given
    /// `seed`.
    pub fn run(&self, backend: Backend, seed: u64) -> Result<f64> {
        self.run_recorded(backend, seed, &crate::obs::Recorder::off()).map(|(m, _)| m)
    }

    /// [`Scenario::run`] with observability: the run goes through the
    /// backend's recorded entry point, so `rec` collects span chains and
    /// the metrics registry (DESIGN.md §13), and the report's embedded
    /// snapshot is returned alongside the metric. A disabled recorder
    /// reproduces [`Scenario::run`] exactly — the conformance suite pins
    /// that the metric is identical either way.
    pub fn run_recorded(
        &self,
        backend: Backend,
        seed: u64,
        rec: &crate::obs::Recorder,
    ) -> Result<(f64, Option<crate::obs::MetricsSnapshot>)> {
        match &self.spec {
            Spec::Plan { net, strategy } => {
                let plan = self.compile_plan(net, *strategy)?;
                match backend {
                    Backend::Des => {
                        let r = plan.simulate_recorded(self.images, self.queue_cap, rec)?;
                        Ok((r.throughput, r.metrics))
                    }
                    Backend::Wall => {
                        let report = plan.deploy_recorded(&self.deploy_opts(seed), rec)?;
                        Ok((report.throughput * self.time_scale, report.metrics))
                    }
                }
            }
            Spec::Adaptive { net, throttle_at, factor } => {
                let cfg = Config::default();
                let network = zoo::by_name(net)
                    .with_context(|| format!("unknown network {net:?}"))?;
                let tm = TimeMatrix::measured(&cfg.platform, &network);
                let plan = PlanSpec::new(net).platform(cfg.clone()).compile()?;
                let opts = AdaptOptions::default();
                match backend {
                    Backend::Des => {
                        let script = [ClusterThrottle {
                            at: *throttle_at,
                            core: CoreType::Big,
                            factor: *factor,
                        }];
                        let out = adapt::simulate_adaptive_recorded(
                            &plan,
                            &tm,
                            &cfg.power,
                            &script,
                            &opts,
                            self.images,
                            self.queue_cap,
                            rec,
                        )?;
                        Ok((out.report.throughput, out.report.metrics))
                    }
                    Backend::Wall => {
                        // Throttle times are simulated seconds; the wall
                        // twin's clock runs at `time_scale` of model time.
                        let script = [ClusterThrottle {
                            at: *throttle_at * self.time_scale,
                            core: CoreType::Big,
                            factor: *factor,
                        }];
                        let out = adapt::deploy_adaptive_recorded(
                            &plan,
                            &tm,
                            &cfg.power,
                            &script,
                            &opts,
                            &self.deploy_opts(seed),
                            rec,
                        )?;
                        Ok((out.report.throughput * self.time_scale, out.report.metrics))
                    }
                }
            }
            Spec::Multi { tenants, max_replicas } => {
                let mp = self.compile_multi(tenants, *max_replicas)?;
                let opts = MultiServeOptions {
                    images: self.images,
                    queue_cap: self.queue_cap,
                    admission_cap: 8,
                    seed,
                    time_scale: self.time_scale,
                    uniform_arrivals: false,
                };
                let report = match backend {
                    Backend::Des => mp.simulate_recorded(&opts, rec)?,
                    Backend::Wall => mp.deploy_recorded(&opts, rec)?,
                };
                Ok((report.weighted_throughput, report.metrics))
            }
            Spec::Cluster { boards, net, saturation, policy } => {
                let cp = self.compile_cluster(boards, net, *saturation)?;
                let opts = ClusterServeOptions {
                    images: self.images,
                    queue_cap: self.queue_cap,
                    seed,
                    time_scale: self.time_scale,
                    policy: *policy,
                    ..Default::default()
                };
                let report = match backend {
                    Backend::Des => cp.simulate_recorded(&opts, rec)?,
                    Backend::Wall => cp.deploy_recorded(&opts, rec)?,
                };
                Ok((report.throughput, report.metrics))
            }
        }
    }

    /// The Eq. 12 upper bound on the scenario's metric: the plan's
    /// predicted aggregate capacity (weighted capacity sum for
    /// multi-tenant). Throttled scenarios report the *clean* capacity,
    /// which still bounds the throttled run from above.
    pub fn capacity(&self) -> Result<f64> {
        match &self.spec {
            Spec::Plan { net, strategy } => {
                Ok(self.compile_plan(net, *strategy)?.throughput)
            }
            Spec::Adaptive { net, .. } => {
                Ok(PlanSpec::new(net).platform(Config::default()).compile()?.throughput)
            }
            Spec::Multi { tenants, max_replicas } => {
                let mp = self.compile_multi(tenants, *max_replicas)?;
                Ok(mp.tenants.iter().map(|t| t.weight * t.plan.throughput).sum())
            }
            Spec::Cluster { boards, net, saturation, .. } => {
                Ok(self.compile_cluster(boards, net, *saturation)?.capacity())
            }
        }
    }

    fn compile_plan(&self, net: &str, strategy: Strategy) -> Result<Plan> {
        PlanSpec::new(net).platform(Config::default()).strategy(strategy).compile()
    }

    fn compile_multi(
        &self,
        tenants: &[(&str, f64)],
        max_replicas: usize,
    ) -> Result<MultiPlan> {
        let specs: Vec<TenantSpec> =
            tenants.iter().map(|(n, r)| TenantSpec::new(n, *r)).collect();
        MultiPlan::compile(&specs, &Config::default(), max_replicas)
    }

    /// Compile the fleet at a placeholder rate, then rescale the workload
    /// to `saturation ×` the fleet's Σ Eq. 12 capacity. Rate shares (and
    /// the single-workload per-board plans) are rate-independent, so the
    /// rescale only changes the offered arrival stream.
    fn compile_cluster(
        &self,
        boards: &[(usize, usize)],
        net: &str,
        saturation: f64,
    ) -> Result<ClusterPlan> {
        let spec = ClusterSpec {
            boards: boards.iter().map(|&(b, s)| BoardSpec::new(b, s)).collect(),
            workloads: vec![TenantSpec::new(net, 1.0)],
            max_replicas: 2,
        };
        let mut cp = ClusterPlan::compile(&spec, &Config::default())?;
        cp.workloads[0].rate_hz = saturation * cp.capacity();
        Ok(cp)
    }

    fn deploy_opts(&self, seed: u64) -> DeployOptions {
        DeployOptions {
            images: self.images,
            queue_cap: self.queue_cap,
            time_scale: self.time_scale,
            batch: 1,
            seed,
        }
    }
}

fn scenario(
    name: &str,
    mode: &'static str,
    images: usize,
    tolerance: f64,
    spec: Spec,
) -> Scenario {
    Scenario {
        name: name.to_string(),
        mode,
        images,
        queue_cap: 2,
        time_scale: 0.05,
        tolerance,
        des_only: false,
        spec,
    }
}

/// Tenant mixes are `&'static` so scenarios stay `Clone` without owning
/// allocations per entry.
static MULTI_MIX: [(&str, f64); 2] = [("alexnet", 30.0), ("squeezenet", 60.0)];

/// Cluster board mixes (big, small core counts per board), `&'static` for
/// the same reason.
static CLUSTER_TWIN_4P4: [(usize, usize); 2] = [(4, 4), (4, 4)];
static CLUSTER_HETERO: [(usize, usize); 2] = [(4, 4), (2, 6)];

/// Every benchmark scenario: one per (serving mode, network) pair worth
/// tracking, spanning all six serving modes shipped so far. Names are
/// unique; each runs on both backends.
pub fn registry() -> Vec<Scenario> {
    vec![
        scenario(
            "serial/alexnet",
            "serial",
            80,
            0.25,
            Spec::Plan { net: "alexnet", strategy: Strategy::Serial },
        ),
        scenario(
            "serial/squeezenet",
            "serial",
            80,
            0.25,
            Spec::Plan { net: "squeezenet", strategy: Strategy::Serial },
        ),
        scenario(
            "pipelined/alexnet",
            "pipelined",
            120,
            0.35,
            Spec::Plan { net: "alexnet", strategy: Strategy::Pipeline },
        ),
        scenario(
            "pipelined/squeezenet",
            "pipelined",
            160,
            0.35,
            Spec::Plan { net: "squeezenet", strategy: Strategy::Pipeline },
        ),
        scenario(
            "pipelined/mobilenet",
            "pipelined",
            160,
            0.35,
            Spec::Plan { net: "mobilenet", strategy: Strategy::Pipeline },
        ),
        scenario(
            "replicated/alexnet",
            "replicated",
            120,
            0.35,
            Spec::Plan {
                net: "alexnet",
                strategy: Strategy::Replicated { max_replicas: 4, exact: false },
            },
        ),
        scenario(
            "replicated/squeezenet",
            "replicated",
            200,
            0.35,
            Spec::Plan {
                net: "squeezenet",
                strategy: Strategy::Replicated { max_replicas: 4, exact: false },
            },
        ),
        scenario(
            "adaptive/squeezenet-throttle2x",
            "adaptive",
            300,
            0.50,
            Spec::Adaptive { net: "squeezenet", throttle_at: 4.0, factor: 2.0 },
        ),
        scenario(
            "multi/alexnet30+squeezenet60",
            "multi-tenant",
            120,
            0.35,
            Spec::Multi { tenants: &MULTI_MIX, max_replicas: 2 },
        ),
        // The event-core throughput stress (DESIGN.md §15): 2 tenants ×
        // 500k arrivals = 1M front-door admissions through the tenancy
        // engine. Runs in seconds on the O(log n) front door — the O(n²)
        // reference scan would make this scenario the whole bench run —
        // and its recorded EngineProf (events/s, scan_iters) is what CI's
        // superlinearity gate reads. DES-only: the wall twin would
        // time-scale-sleep through a seven-figure stream.
        Scenario {
            des_only: true,
            ..scenario(
                "multi/hot-2x500k",
                "multi-tenant",
                500_000,
                0.35,
                Spec::Multi { tenants: &MULTI_MIX, max_replicas: 2 },
            )
        },
        scenario(
            "cluster/alexnet-2x4+4",
            "cluster",
            200,
            0.35,
            Spec::Cluster {
                boards: &CLUSTER_TWIN_4P4,
                net: "alexnet",
                saturation: 3.0,
                policy: DispatchPolicy::LeastOutstanding,
            },
        ),
        scenario(
            "cluster/squeezenet-4+4,2+6-p2c",
            "cluster",
            200,
            0.35,
            Spec::Cluster {
                boards: &CLUSTER_HETERO,
                net: "squeezenet",
                saturation: 3.0,
                policy: DispatchPolicy::PowerOfTwo,
            },
        ),
    ]
}

/// Which (scenario, backend) entries a bench run executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Suite {
    /// Every scenario on the DES twin only: pure deterministic computation
    /// (same seed, same binary, bit-identical samples) — the CI
    /// determinism gate runs this.
    Quick,
    /// The quick suite plus every wall-clock twin (real threads, real
    /// sleeps; the robust statistics exist for these). Scenarios marked
    /// [`Scenario::des_only`] contribute no wall entry.
    Full,
}

impl Suite {
    pub fn parse(s: &str) -> Result<Suite> {
        match s {
            "quick" => Ok(Suite::Quick),
            "full" => Ok(Suite::Full),
            other => Err(anyhow::anyhow!("unknown suite {other:?} (quick|full)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Suite::Quick => "quick",
            Suite::Full => "full",
        }
    }
}

/// One unit of bench work: a scenario pinned to a backend.
#[derive(Debug, Clone)]
pub struct SuiteEntry {
    pub scenario: Scenario,
    pub backend: Backend,
}

/// The suite's entries in a stable order (registry order, DES before wall).
pub fn suite_entries(suite: Suite) -> Vec<SuiteEntry> {
    let reg = registry();
    let wall: Vec<SuiteEntry> = if suite == Suite::Full {
        reg.iter()
            .filter(|s| !s.des_only)
            .cloned()
            .map(|scenario| SuiteEntry { scenario, backend: Backend::Wall })
            .collect()
    } else {
        Vec::new()
    };
    let mut out: Vec<SuiteEntry> = reg
        .into_iter()
        .map(|scenario| SuiteEntry { scenario, backend: Backend::Des })
        .collect();
    out.extend(wall);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_the_issue_floor() {
        let reg = registry();
        assert!(reg.len() >= 12, "only {} scenarios", reg.len());
        let mut modes: Vec<&str> = reg.iter().map(|s| s.mode).collect();
        modes.sort_unstable();
        modes.dedup();
        assert!(modes.len() >= 6, "only {} modes: {modes:?}", modes.len());
        assert!(modes.contains(&"cluster"), "cluster mode missing: {modes:?}");
        let mut names: Vec<&String> = reg.iter().map(|s| &s.name).collect();
        names.sort();
        let n = names.len();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate scenario names");
        for s in &reg {
            assert!(s.tolerance > 0.0 && s.tolerance < 1.0);
            assert!(s.images >= 1 && s.time_scale > 0.0);
        }
    }

    #[test]
    fn quick_suite_is_des_only_and_full_extends_it() {
        let quick = suite_entries(Suite::Quick);
        assert!(quick.iter().all(|e| e.backend == Backend::Des));
        assert_eq!(quick.len(), registry().len());
        let full = suite_entries(Suite::Full);
        let wall_eligible = registry().iter().filter(|s| !s.des_only).count();
        assert!(wall_eligible < quick.len(), "a des_only stress scenario exists");
        assert_eq!(full.len(), quick.len() + wall_eligible);
        for (q, f) in quick.iter().zip(&full) {
            assert_eq!(q.scenario.name, f.scenario.name, "full must extend quick");
        }
        assert!(
            full.iter().all(|e| e.backend != Backend::Wall || !e.scenario.des_only),
            "des_only scenarios must never get a wall entry"
        );
    }

    #[test]
    fn hot_scenario_offers_a_seven_figure_event_stream() {
        let reg = registry();
        let hot = reg.iter().find(|s| s.name == "multi/hot-2x500k").unwrap();
        assert!(hot.des_only, "the wall twin would sleep through 1M items");
        assert!(
            hot.images >= 500_000,
            "the regression gate needs >= 1M arrivals across 2 tenants"
        );
        assert_eq!(hot.mode, "multi-tenant");
    }

    #[test]
    fn suite_parse_roundtrips_and_rejects_garbage() {
        assert_eq!(Suite::parse("quick").unwrap(), Suite::Quick);
        assert_eq!(Suite::parse("full").unwrap(), Suite::Full);
        assert_eq!(Suite::Quick.name(), "quick");
        assert!(Suite::parse("nightly").is_err());
    }

    #[test]
    fn des_run_is_deterministic_and_capacity_bounded() {
        // One representative per spec kind (full coverage lives in the
        // differential suite, which also runs the wall twin).
        for name in [
            "pipelined/alexnet",
            "multi/alexnet30+squeezenet60",
            "cluster/alexnet-2x4+4",
        ] {
            let s = registry().into_iter().find(|s| s.name == name).unwrap();
            let a = s.run(Backend::Des, 7).unwrap();
            let b = s.run(Backend::Des, 7).unwrap();
            assert_eq!(a, b, "{name}: DES must be bit-deterministic");
            assert!(a > 0.0, "{name}: zero metric");
            let cap = s.capacity().unwrap();
            assert!(a <= cap * 1.05, "{name}: metric {a} above capacity {cap}");
        }
    }
}
