//! The bench runner: warmup + repetition control over the scenario
//! registry, robust statistics per entry, one [`BenchReport`] out.
//!
//! Two front ends share the machinery:
//!
//! * [`run_suite`] — the `pipeit bench` path: run every (scenario,
//!   backend) entry of a [`Suite`] `reps` times (after `warmup` discarded
//!   runs), summarize each sample set with MAD outlier rejection and a
//!   seeded bootstrap CI ([`SampleStats::from_samples`]).
//! * [`HostBench`] — the `cargo bench` path: a criterion-style
//!   micro-benchmark timer (calibrated iteration counts against a time
//!   budget) that emits the same [`ScenarioResult`] shape, so the bench
//!   targets are thin wrappers over this module and print through
//!   [`crate::reports::render_bench`].
//!
//! Determinism: repetition `r` of a scenario runs with seed
//! `base_seed + r`, and the bootstrap is seeded from `base_seed` XOR a
//! stable FNV-1a hash of the entry key — so two runs of the same suite at
//! the same seed produce bit-identical samples AND bit-identical
//! confidence intervals, which is exactly what the CI determinism gate
//! (`--compare` reporting all-unchanged) relies on.

use std::time::{Duration, Instant};

use anyhow::Result;

use super::report::{BenchReport, SampleStats, ScenarioResult};
use super::scenario::{suite_entries, Backend, Suite};

/// Knobs for [`run_suite`]; the defaults are what `pipeit bench` uses.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RunnerOptions {
    /// Discarded runs per entry before sampling starts.
    pub warmup: usize,
    /// Measured repetitions per entry.
    pub reps: usize,
    /// Base seed: repetition `r` runs with `seed + r`.
    pub seed: u64,
    /// MAD outlier-rejection multiplier ([`crate::util::stats::mad_filter`]).
    pub mad_k: f64,
    /// Bootstrap CI confidence level.
    pub confidence: f64,
    /// Bootstrap resamples.
    pub resamples: usize,
}

impl Default for RunnerOptions {
    fn default() -> RunnerOptions {
        RunnerOptions {
            warmup: 1,
            reps: 5,
            seed: 7,
            mad_k: 3.5,
            confidence: 0.95,
            resamples: 200,
        }
    }
}

/// Stable 64-bit FNV-1a — the bootstrap-seed hash must not depend on the
/// standard library's unspecified default hasher.
pub(crate) fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Run every entry of `suite` and produce the serializable artifact.
/// Entries run sequentially in suite order (wall-clock scenarios spawn
/// their own thread fleets; overlapping them would contaminate timings).
pub fn run_suite(suite: Suite, opts: &RunnerOptions) -> Result<BenchReport> {
    anyhow::ensure!(opts.reps >= 1, "need at least one repetition");
    // Rep seeds are `base + rep`; boards/tenants derive theirs at strides
    // of 7919 and 7919² from the same base, so reps must stay below the
    // first stride for the mixed-radix disjointness argument to hold
    // (seed-stream audit, DESIGN.md §15).
    anyhow::ensure!(
        opts.reps < 7919,
        "reps must stay below the 7919 seed stride (got {})",
        opts.reps
    );
    let mut scenarios = Vec::new();
    let mut recorded_rep = None;
    for e in suite_entries(suite) {
        let started = Instant::now();
        for _ in 0..opts.warmup {
            e.scenario.run(e.backend, opts.seed)?;
        }
        let mut samples = Vec::with_capacity(opts.reps);
        // The last DES repetition runs recorded so the artifact carries a
        // registry snapshot; recording never changes the DES metric (the
        // conformance suite pins this), and wall entries stay unrecorded
        // to keep the observer off their timed hot paths.
        let mut metrics = None;
        for rep in 0..opts.reps {
            let seed = opts.seed.wrapping_add(rep as u64);
            if e.backend == Backend::Des && rep + 1 == opts.reps {
                let rec = crate::obs::Recorder::on();
                let (m, snap) = e.scenario.run_recorded(e.backend, seed, &rec)?;
                samples.push(m);
                metrics = snap;
                recorded_rep = Some(rep);
            } else {
                samples.push(e.scenario.run(e.backend, seed)?);
            }
        }
        let key = format!("{}/{}", e.backend.key(), e.scenario.name);
        let stats = SampleStats::from_samples(
            &samples,
            opts.mad_k,
            opts.confidence,
            opts.resamples,
            opts.seed ^ fnv1a(&key),
        );
        scenarios.push(ScenarioResult {
            name: e.scenario.name.clone(),
            mode: e.scenario.mode.to_string(),
            backend: e.backend.key().to_string(),
            unit: "imgs/s".to_string(),
            higher_is_better: true,
            samples,
            stats,
            host_s: started.elapsed().as_secs_f64(),
            metrics,
        });
    }
    Ok(BenchReport {
        suite: suite.name().to_string(),
        seed: opts.seed,
        warmup: opts.warmup,
        reps: opts.reps,
        recorded_rep,
        scenarios,
    })
}

/// Opaque value sink that defeats dead-code elimination in benched
/// closures (std's `black_box`, wrapped so bench code reads uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Criterion-style micro-benchmark runner (criterion is not in the offline
/// vendor set): calibrates an iteration count against a time budget during
/// warmup, then measures per-iteration latency and summarizes it with the
/// same robust statistics as the scenario runner. The `cargo bench`
/// targets are thin wrappers over this.
pub struct HostBench {
    warmup: Duration,
    budget: Duration,
    max_iters: usize,
    mad_k: f64,
    confidence: f64,
    resamples: usize,
    pub results: Vec<ScenarioResult>,
}

impl Default for HostBench {
    fn default() -> HostBench {
        HostBench::with_budget(Duration::from_millis(100), Duration::from_millis(600), 10_000)
    }
}

impl HostBench {
    pub fn new() -> HostBench {
        HostBench::default()
    }

    /// Tiny budget for unit-ish benches in CI.
    pub fn quick() -> HostBench {
        HostBench::with_budget(Duration::from_millis(10), Duration::from_millis(80), 1000)
    }

    pub fn with_budget(warmup: Duration, budget: Duration, max_iters: usize) -> HostBench {
        HostBench {
            warmup,
            budget,
            max_iters,
            mad_k: 3.5,
            confidence: 0.95,
            resamples: 200,
            results: Vec::new(),
        }
    }

    /// Time `f`: warmup until the warmup budget elapses (calibrating the
    /// iteration count), then measure per-iteration seconds. Host timing is
    /// inherently noisy — this is precisely what the MAD rejection and the
    /// bootstrap CI are for. Prints a one-line summary and records the
    /// result (unit `s`, lower is better; raw samples are not retained —
    /// iteration counts are large).
    pub fn time<F: FnMut()>(&mut self, name: &str, mut f: F) -> &ScenarioResult {
        let started = Instant::now();
        let mut warm_iters = 0usize;
        while started.elapsed() < self.warmup {
            f();
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = started.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.budget.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(5, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let stats = SampleStats::from_samples(
            &samples,
            self.mad_k,
            self.confidence,
            self.resamples,
            fnv1a(name),
        );
        println!(
            "bench {:<44} n={:<6} median={:>12?} ci95=[{:?}, {:?}] mad={:?}",
            name,
            stats.n,
            Duration::from_secs_f64(stats.median),
            Duration::from_secs_f64(stats.ci_lo),
            Duration::from_secs_f64(stats.ci_hi),
            Duration::from_secs_f64(stats.mad),
        );
        self.results.push(ScenarioResult {
            name: name.to_string(),
            mode: "micro".to_string(),
            backend: "host".to_string(),
            unit: "s".to_string(),
            higher_is_better: false,
            samples: Vec::new(),
            stats,
            host_s: started.elapsed().as_secs_f64(),
            metrics: None,
        });
        self.results.last().expect("just pushed")
    }

    /// Package the recorded results as a [`BenchReport`] (suite = the bench
    /// target's name). Seed 0: host timings are not reproducible anyway.
    pub fn into_report(self, suite: &str) -> BenchReport {
        BenchReport {
            suite: suite.to_string(),
            seed: 0,
            warmup: 0,
            reps: 0,
            recorded_rep: None,
            scenarios: self.results,
        }
    }

    /// The shared epilogue of every `cargo bench` target: package, render
    /// through [`crate::reports::render_bench`], persist when `BENCH_OUT`
    /// is set, and hand the report back.
    pub fn finish(self, suite: &str) -> Result<BenchReport> {
        let report = self.into_report(suite);
        println!();
        print!("{}", crate::reports::render_bench(&report));
        save_if_requested(&report)?;
        Ok(report)
    }
}

/// Honor `BENCH_OUT=<path>`: the bench targets call this so any `cargo
/// bench` run can be captured as a machine-readable artifact.
pub fn save_if_requested(report: &BenchReport) -> Result<()> {
    if let Ok(path) = std::env::var("BENCH_OUT") {
        report.save(std::path::Path::new(&path))?;
        println!("bench saved : {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::compare::{self, Verdict};

    #[test]
    fn fnv1a_is_stable_and_discriminates() {
        // Pinned value: the bootstrap seed derivation must never drift
        // between builds, or historical artifacts stop being comparable.
        assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a("a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a("des/pipelined/alexnet"), fnv1a("wall/pipelined/alexnet"));
    }

    #[test]
    fn host_bench_runs_and_records_robust_stats() {
        let mut b = HostBench::quick();
        let r = b.time("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.stats.n >= 5);
        assert!(r.stats.median > 0.0);
        assert!(r.stats.ci_lo <= r.stats.median && r.stats.median <= r.stats.ci_hi);
        assert!(!r.higher_is_better);
        let report = b.into_report("hotpath");
        assert_eq!(report.suite, "hotpath");
        assert_eq!(report.scenarios.len(), 1);
    }

    #[test]
    fn host_bench_slower_code_measures_slower() {
        let mut b = HostBench::quick();
        let fast = b
            .time("fast", || {
                black_box((0..10u64).sum::<u64>());
            })
            .stats
            .median;
        let slow = b
            .time("slow", || {
                // black_box on the bound + accumulator defeats
                // const-folding in release builds.
                let n = black_box(200_000u64);
                let mut acc = 0u64;
                for i in 0..n {
                    acc = acc.wrapping_add(black_box(i).wrapping_mul(3));
                }
                black_box(acc);
            })
            .stats
            .median;
        assert!(slow > fast);
    }

    /// The acceptance loop in miniature, without the CLI: two same-seed
    /// quick-suite runs compare as all-unchanged; a synthetic 10% slowdown
    /// on one scenario is flagged as a regression. The full-size version
    /// (real suite, real binary) lives in `tests/bench_harness.rs`; this
    /// one uses hand-built reports so `cargo test` stays fast.
    #[test]
    fn compare_contract_on_hand_built_reports() {
        let samples = vec![20.0, 20.0, 20.0];
        let entry = |median_scale: f64| {
            let scaled: Vec<f64> = samples.iter().map(|x| x * median_scale).collect();
            ScenarioResult {
                name: "pipelined/alexnet".into(),
                mode: "pipelined".into(),
                backend: "des".into(),
                unit: "imgs/s".into(),
                higher_is_better: true,
                stats: SampleStats::from_samples(&scaled, 3.5, 0.95, 100, 3),
                samples: scaled,
                host_s: 0.1,
                metrics: None,
            }
        };
        let report = |scale: f64| BenchReport {
            suite: "quick".into(),
            seed: 7,
            warmup: 0,
            reps: 3,
            recorded_rep: None,
            scenarios: vec![entry(scale)],
        };
        let base = report(1.0);
        let same = compare::compare(&base, &report(1.0), 0.01);
        assert!(!same.has_regressions());
        assert!(same.diffs.iter().all(|d| d.verdict == Verdict::Unchanged));

        let slow = compare::compare(&base, &report(0.9), 0.01);
        assert!(slow.has_regressions());
        assert_eq!(slow.diffs[0].verdict, Verdict::Regressed);

        let fast = compare::compare(&base, &report(1.1), 0.01);
        assert!(!fast.has_regressions());
        assert_eq!(fast.diffs[0].verdict, Verdict::Improved);
    }
}
