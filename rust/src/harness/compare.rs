//! The regression gate: classify each scenario of two bench artifacts as
//! improved / regressed / unchanged by CONFIDENCE-INTERVAL OVERLAP, not
//! point deltas.
//!
//! A point-delta gate flags every noisy wobble; a CI gate only speaks when
//! the two runs' bootstrap intervals are disjoint AND the median moved by
//! more than a floor (`min_rel_delta`, guarding against spuriously tight
//! zero-width intervals on deterministic scenarios). Direction respects
//! each entry's metric: lower is worse for throughput, higher is worse for
//! time-like micro benches.

use std::fmt;

use super::report::{BenchReport, ScenarioResult};

/// Default relative-median floor below which a disjoint-CI shift is still
/// called unchanged (1%): deterministic DES scenarios have zero-width
/// intervals, so without a floor a 1e-15 wobble would gate a merge.
pub const DEFAULT_MIN_REL_DELTA: f64 = 0.01;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Improved,
    Regressed,
    Unchanged,
}

impl fmt::Display for Verdict {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Verdict::Improved => write!(f, "improved"),
            Verdict::Regressed => write!(f, "REGRESSED"),
            Verdict::Unchanged => write!(f, "unchanged"),
        }
    }
}

/// One matched scenario's classification.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioDiff {
    pub name: String,
    pub mode: String,
    pub backend: String,
    pub unit: String,
    pub old_median: f64,
    pub new_median: f64,
    /// `(new - old) / old`; 0.0 when the old median is 0.
    pub rel_delta: f64,
    pub verdict: Verdict,
}

/// Result of comparing two bench artifacts.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchComparison {
    /// Matched scenarios in the OLD report's order.
    pub diffs: Vec<ScenarioDiff>,
    /// Keys present only in the new report (no baseline — never a gate).
    pub added: Vec<String>,
    /// Keys present only in the old report (dropped scenarios — reported,
    /// never a gate).
    pub removed: Vec<String>,
}

impl BenchComparison {
    pub fn count(&self, v: Verdict) -> usize {
        self.diffs.iter().filter(|d| d.verdict == v).count()
    }

    /// The exit-code question: did anything get worse?
    pub fn has_regressions(&self) -> bool {
        self.count(Verdict::Regressed) > 0
    }
}

fn classify(old: &ScenarioResult, new: &ScenarioResult, min_rel_delta: f64) -> (f64, Verdict) {
    let rel = if old.stats.median != 0.0 {
        (new.stats.median - old.stats.median) / old.stats.median
    } else {
        0.0
    };
    // Disjoint intervals are the significance test; the floor keeps
    // zero-width (deterministic) intervals from gating on float dust.
    let below = new.stats.ci_hi < old.stats.ci_lo;
    let above = new.stats.ci_lo > old.stats.ci_hi;
    if rel.abs() <= min_rel_delta || (!below && !above) {
        return (rel, Verdict::Unchanged);
    }
    let worse = if old.higher_is_better { below } else { above };
    (rel, if worse { Verdict::Regressed } else { Verdict::Improved })
}

/// Compare two artifacts, matching entries by `backend/name` key. Suites
/// need not be identical: unmatched keys land in `added` / `removed` and
/// never trip the gate — only a matched scenario that got significantly
/// worse does.
pub fn compare(old: &BenchReport, new: &BenchReport, min_rel_delta: f64) -> BenchComparison {
    let mut diffs = Vec::new();
    let mut removed = Vec::new();
    for o in &old.scenarios {
        match new.find(&o.key()) {
            Some(n) => {
                let (rel_delta, verdict) = classify(o, n, min_rel_delta);
                diffs.push(ScenarioDiff {
                    name: o.name.clone(),
                    mode: o.mode.clone(),
                    backend: o.backend.clone(),
                    unit: o.unit.clone(),
                    old_median: o.stats.median,
                    new_median: n.stats.median,
                    rel_delta,
                    verdict,
                });
            }
            None => removed.push(o.key()),
        }
    }
    let added = new
        .scenarios
        .iter()
        .filter(|n| old.find(&n.key()).is_none())
        .map(|n| n.key())
        .collect();
    BenchComparison { diffs, added, removed }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::report::SampleStats;

    fn entry(name: &str, samples: &[f64], higher_is_better: bool) -> ScenarioResult {
        ScenarioResult {
            name: name.to_string(),
            mode: "pipelined".into(),
            backend: "des".into(),
            unit: if higher_is_better { "imgs/s" } else { "s" }.into(),
            higher_is_better,
            samples: samples.to_vec(),
            stats: SampleStats::from_samples(samples, 3.5, 0.95, 150, 11),
            host_s: 0.0,
            metrics: None,
        }
    }

    fn report(entries: Vec<ScenarioResult>) -> BenchReport {
        BenchReport {
            suite: "quick".into(),
            seed: 7,
            warmup: 0,
            reps: 3,
            recorded_rep: None,
            scenarios: entries,
        }
    }

    #[test]
    fn identical_runs_are_all_unchanged() {
        let a = report(vec![entry("x", &[10.0, 10.0, 10.0], true)]);
        let c = compare(&a, &a.clone(), DEFAULT_MIN_REL_DELTA);
        assert_eq!(c.count(Verdict::Unchanged), 1);
        assert!(!c.has_regressions());
        assert!(c.added.is_empty() && c.removed.is_empty());
    }

    #[test]
    fn ten_percent_throughput_drop_is_a_regression() {
        let old = report(vec![entry("x", &[10.0, 10.0, 10.0], true)]);
        let new = report(vec![entry("x", &[9.0, 9.0, 9.0], true)]);
        let c = compare(&old, &new, DEFAULT_MIN_REL_DELTA);
        assert_eq!(c.diffs[0].verdict, Verdict::Regressed);
        assert!((c.diffs[0].rel_delta + 0.1).abs() < 1e-12);
        assert!(c.has_regressions());
    }

    #[test]
    fn direction_flips_for_time_like_metrics() {
        // A lower time is an improvement, a higher time a regression.
        let old = report(vec![entry("t", &[1.0, 1.0, 1.0], false)]);
        let faster = report(vec![entry("t", &[0.8, 0.8, 0.8], false)]);
        let slower = report(vec![entry("t", &[1.3, 1.3, 1.3], false)]);
        assert_eq!(
            compare(&old, &faster, DEFAULT_MIN_REL_DELTA).diffs[0].verdict,
            Verdict::Improved
        );
        assert_eq!(
            compare(&old, &slower, DEFAULT_MIN_REL_DELTA).diffs[0].verdict,
            Verdict::Regressed
        );
    }

    #[test]
    fn overlapping_intervals_stay_unchanged_even_with_big_deltas() {
        // Wide, noisy samples whose CIs overlap: no verdict either way.
        let old = report(vec![entry("n", &[8.0, 12.0, 10.0, 9.0, 11.0], true)]);
        let new = report(vec![entry("n", &[7.5, 11.5, 9.5, 8.5, 10.5], true)]);
        let c = compare(&old, &new, DEFAULT_MIN_REL_DELTA);
        assert_eq!(c.diffs[0].verdict, Verdict::Unchanged);
    }

    #[test]
    fn sub_floor_shifts_are_unchanged_despite_disjoint_intervals() {
        // Deterministic zero-width CIs, 0.5% drift: below the 1% floor.
        let old = report(vec![entry("d", &[100.0, 100.0, 100.0], true)]);
        let new = report(vec![entry("d", &[99.5, 99.5, 99.5], true)]);
        let c = compare(&old, &new, DEFAULT_MIN_REL_DELTA);
        assert_eq!(c.diffs[0].verdict, Verdict::Unchanged);
        assert!(!c.has_regressions());
    }

    #[test]
    fn added_and_removed_scenarios_never_gate() {
        let old = report(vec![entry("kept", &[5.0], true), entry("gone", &[5.0], true)]);
        let new = report(vec![entry("kept", &[5.0], true), entry("fresh", &[5.0], true)]);
        let c = compare(&old, &new, DEFAULT_MIN_REL_DELTA);
        assert_eq!(c.removed, vec!["des/gone".to_string()]);
        assert_eq!(c.added, vec!["des/fresh".to_string()]);
        assert!(!c.has_regressions());
        assert_eq!(c.diffs.len(), 1);
    }
}
