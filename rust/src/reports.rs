//! Report generation for every table and figure in the paper's evaluation
//! (the per-experiment index in DESIGN.md §5), plus the renderer for the
//! unified serving report ([`render_serve`]). Shared by the CLI, the bench
//! targets, and the examples, so the numbers printed everywhere come from
//! one code path.

use crate::api::{ServeMode, ServeReport};
use crate::baselines;
use crate::cluster::{ClusterServeMode, ClusterServeReport};
use crate::harness::{BenchComparison, BenchHistory, BenchReport, Verdict};
use crate::obs::{AttribReport, MetricsSnapshot};
use crate::tenancy::{MultiServeMode, MultiServeReport};
use crate::cnn::layer::LayerKind;
use crate::cnn::zoo;
use crate::config::Config;
use crate::dse;
use crate::perfmodel::{PerfModel, TimeMatrix};
use crate::simulator::platform::CoreType;
use crate::simulator::power::ClusterActivity;
use crate::simulator::{gemm, pipeline_sim};
use crate::util::stats;
use crate::util::table::{f, Table};

/// Render the unified [`ServeReport`] — the ONE print shape for
/// single-pipeline runs, fleet runs, and discrete-event simulations, used
/// by the CLI (`serve`, `simulate`) and the examples. A single pipeline is
/// a one-replica fleet, so the output always reads the same way.
pub fn render_serve(r: &ServeReport) -> String {
    let mode = match r.mode {
        ServeMode::Des => "DES".to_string(),
        ServeMode::Synthetic { time_scale } => {
            format!("wall-clock, time-scale {time_scale}")
        }
        ServeMode::Pjrt { serial: true } => "PJRT, serial".to_string(),
        ServeMode::Pjrt { serial: false } => "PJRT".to_string(),
    };
    let mut s = format!(
        "fleet: {} replicas, images={} wall={:.3}s aggregate={:.2} imgs/s ({mode})\n",
        r.replicas.len(),
        r.images,
        r.wall_s,
        r.throughput
    );
    if r.predicted_throughput > 0.0 {
        s.push_str(&format!(
            "eq12 tp    : {:.2} imgs/s aggregate (plan prediction)\n",
            r.predicted_throughput
        ));
    }
    match r.mode {
        ServeMode::Des => s.push_str(&format!(
            "sim tp     : {:.2} imgs/s over {} images (DES)\n",
            r.throughput, r.images
        )),
        ServeMode::Synthetic { time_scale } => s.push_str(&format!(
            "wall-clock : {:.2} imgs/s at time-scale {time_scale} (~{:.2} imgs/s unscaled)\n",
            r.throughput,
            r.throughput * time_scale
        )),
        ServeMode::Pjrt { .. } => {}
    }
    if let Some(l) = r.latency {
        s.push_str(&format!(
            "latency p50={:.1}ms p95={:.1}ms p99={:.1}ms\n",
            l.p50 * 1e3,
            l.p95 * 1e3,
            l.p99 * 1e3,
        ));
    }
    for a in &r.adaptations {
        s.push_str(&format!(
            "adapt      : t={:.2}s after {} imgs  {}  {} -> {}  (pred {:.2} imgs/s)\n",
            a.at_s, a.after_images, a.disturbance, a.from, a.to, a.predicted_throughput,
        ));
    }
    if !r.adaptations.is_empty() {
        s.push_str("(replica detail below describes the final partition)\n");
    }
    for (i, rep) in r.replicas.iter().enumerate() {
        let bottleneck = rep
            .bottleneck
            .map(|j| format!("  bottleneck=stage {j}"))
            .unwrap_or_default();
        s.push_str(&format!(
            "replica {i}: {:<10} alloc {}  dispatched={} throughput={:.2} imgs/s util={:.0}%{bottleneck}\n",
            rep.pipeline,
            rep.allocation,
            rep.dispatched,
            rep.throughput,
            100.0 * rep.utilization,
        ));
        for st in &rep.stages {
            s.push_str(&format!(
                "  stage {:<14} items={:<6} busy={:>8.3}s util={:>5.1}%\n",
                st.name,
                st.items,
                st.busy_s,
                100.0 * st.utilization,
            ));
        }
    }
    if let Some(a) = &r.attrib {
        s.push_str(&render_attrib(a));
    }
    s
}

/// Render the unified [`MultiServeReport`] — the ONE print shape for
/// multi-tenant co-serving, shared by the DES co-simulation
/// (`simulate-multi`, single-tenant `--arrival` runs) and the wall-clock
/// deploy (`serve-multi`).
pub fn render_multi_serve(r: &MultiServeReport) -> String {
    let mode = match r.mode {
        MultiServeMode::Des => "DES".to_string(),
        MultiServeMode::Synthetic { time_scale } => {
            format!("wall-clock, time-scale {time_scale}, normalized")
        }
    };
    let mut s = format!(
        "co-serving : {} tenants, served={} shed={} wall={:.3}s ({mode})\n",
        r.tenants.len(),
        r.images,
        r.shed,
        r.wall_s
    );
    s.push_str(&format!(
        "objective  : {:.2} weighted imgs/s observed\n",
        r.weighted_throughput
    ));
    let (met, declared) = r.sla_counts();
    if declared > 0 {
        s.push_str(&format!("SLAs       : {met}/{declared} met\n"));
    }
    s.push_str(&format!(
        "board util : {:.0}% busy core-seconds\n",
        100.0 * r.board_utilization
    ));
    for t in &r.tenants {
        s.push_str(&format!(
            "tenant {:<12} {:<6} {}  rate={:.1}/s w={:.1}\n",
            t.name, t.budget, t.pipeline, t.rate_hz, t.weight
        ));
        s.push_str(&format!(
            "  served {:.2}/s (cap {:.2} eq12)  admitted={} shed={} util={:.0}%\n",
            t.throughput,
            t.capacity,
            t.admitted,
            t.shed,
            100.0 * t.utilization
        ));
        if let Some(l) = t.latency {
            let sla = match (t.p99_sla_s, t.sla_ok) {
                (Some(sla), Some(ok)) => format!(
                    "  SLA p99<={:.0}ms: {}",
                    sla * 1e3,
                    if ok { "OK" } else { "VIOLATED" }
                ),
                _ => String::new(),
            };
            s.push_str(&format!(
                "  latency p50={:.1}ms p95={:.1}ms p99={:.1}ms{sla}\n",
                l.p50 * 1e3,
                l.p95 * 1e3,
                l.p99 * 1e3
            ));
        }
    }
    if let Some(a) = &r.attrib {
        s.push_str(&render_attrib(a));
    }
    s
}

/// Render the unified [`ClusterServeReport`] — the ONE print shape for
/// cluster serving, shared by the DES co-simulation (`simulate-cluster`)
/// and the wall-clock deploy (`serve-cluster`).
pub fn render_cluster(r: &ClusterServeReport) -> String {
    let mode = match r.mode {
        ClusterServeMode::Des => "DES".to_string(),
        ClusterServeMode::Synthetic { time_scale } => {
            format!("wall-clock, time-scale {time_scale}, normalized")
        }
    };
    let mut s = format!(
        "cluster    : {} boards, served={} shed={} wall={:.3}s ({mode})\n",
        r.boards.len(),
        r.images,
        r.shed,
        r.wall_s
    );
    s.push_str(&format!("policy     : {}\n", r.policy.name()));
    s.push_str(&format!(
        "aggregate  : {:.2} imgs/s vs {:.2} Σ eq12 capacity ({:.0}%)\n",
        r.throughput,
        r.capacity,
        if r.capacity > 0.0 { 100.0 * r.throughput / r.capacity } else { 0.0 }
    ));
    if let Some(l) = r.latency {
        s.push_str(&format!(
            "latency    : p50={:.1}ms p95={:.1}ms p99={:.1}ms (merged)\n",
            l.p50 * 1e3,
            l.p95 * 1e3,
            l.p99 * 1e3
        ));
    }
    for b in &r.boards {
        let down = if b.up { "" } else { "  [down]" };
        s.push_str(&format!(
            "board {:<12} {:<6} {}  share={:.2}  cap {:.2}/s{down}\n",
            b.name, b.budget, b.pipeline, b.rate_share, b.capacity
        ));
        s.push_str(&format!(
            "  served {:.2}/s  offered={} admitted={} shed={} util={:.0}%\n",
            b.throughput,
            b.offered,
            b.admitted,
            b.shed,
            100.0 * b.utilization
        ));
        if let Some(l) = b.latency {
            s.push_str(&format!(
                "  latency p50={:.1}ms p95={:.1}ms p99={:.1}ms\n",
                l.p50 * 1e3,
                l.p95 * 1e3,
                l.p99 * 1e3
            ));
        }
    }
    if let Some(a) = &r.attrib {
        s.push_str(&render_attrib(a));
    }
    s
}

/// Render an [`AttribReport`] — the explanation footer the serve-family
/// renderers append when a DES run carried attribution, and the body of
/// `pipeit attrib` (DESIGN.md §14). The first line decomposes the mean
/// end-to-end latency; the conservation line pins the telescoping
/// invariant the `obs_tracing` suite asserts at 1e-9; the table ranks
/// `(group, replica, stage)` rows by the seconds of run time their
/// Eq. 10 miss cost (residual x items), biggest miss first.
pub fn render_attrib(a: &AttribReport) -> String {
    let mut s = format!(
        "attribution: items={} shed={}  latency {:.1}ms = front {:.1}ms + queue {:.1}ms + service {:.1}ms (means)\n",
        a.items,
        a.shed,
        a.latency_s * 1e3,
        a.front_wait_s * 1e3,
        a.queue_wait_s * 1e3,
        a.service_s * 1e3,
    );
    s.push_str(&format!(
        "conserved  : max |front+queue+service - latency| = {:.1e}s\n",
        a.max_abs_err_s
    ));
    for note in &a.annotations {
        s.push_str(&format!("note       : {note}\n"));
    }
    if !a.stages.is_empty() {
        let mut t = Table::new(
            "Observed stage service vs Eq. 10 prediction (biggest |excess| first)",
            &["stage", "items", "obs ms", "pred ms", "resid ms", "excess s"],
        );
        for st in &a.stages {
            let (pred, resid) = match st.predicted_s {
                Some(p) => (
                    format!("{:.2}", p * 1e3),
                    format!("{:+.2}", st.residual_s * 1e3),
                ),
                None => ("-".to_string(), "-".to_string()),
            };
            t.row(vec![
                format!("g{}r{}s{}", st.group, st.replica, st.stage),
                st.items.to_string(),
                format!("{:.2}", st.observed_s * 1e3),
                pred,
                resid,
                format!("{:+.3}", st.excess_s),
            ]);
        }
        s.push_str(&t.render());
    }
    s
}

/// Render a [`BenchHistory`] — the `pipeit bench history` table: one row
/// per scenario (`backend/name`, first-seen order), one column per
/// artifact (medians in the scenario's unit), and the first->last
/// relative delta. `-` marks artifacts that do not carry the scenario;
/// the delta needs at least two carrying artifacts.
pub fn render_history(h: &BenchHistory) -> String {
    let keys = h.keys();
    let mut s = format!(
        "bench history: {} artifacts, {} scenarios\n",
        h.entries.len(),
        keys.len()
    );
    let mut header = vec!["scenario".to_string(), "unit".to_string()];
    header.extend(h.entries.iter().map(|e| e.label.clone()));
    header.push("first->last".to_string());
    let mut t = Table::new(
        "Bench trajectory (median per artifact)",
        &header.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    for k in &keys {
        let unit = (0..h.entries.len())
            .find_map(|i| h.scenario(i, k))
            .map(|sc| sc.unit.clone())
            .unwrap_or_default();
        let medians: Vec<Option<f64>> =
            (0..h.entries.len()).map(|i| h.median(i, k)).collect();
        let mut row = vec![k.clone(), unit.clone()];
        row.extend(
            medians
                .iter()
                .map(|m| m.map_or_else(|| "-".to_string(), |x| fmt_metric(x, &unit))),
        );
        let present: Vec<f64> = medians.iter().flatten().copied().collect();
        row.push(match (present.first(), present.last()) {
            (Some(&first), Some(&last)) if present.len() >= 2 && first != 0.0 => {
                format!("{:+.1}%", 100.0 * (last / first - 1.0))
            }
            _ => "-".to_string(),
        });
        t.row(row);
    }
    s.push_str(&t.render());
    s
}

/// Format a metric in its unit: throughput with two decimals, time-like
/// micro-bench values in engineering notation.
fn fmt_metric(x: f64, unit: &str) -> String {
    if unit == "s" {
        format!("{x:.3e}")
    } else {
        f(x, 2)
    }
}

/// Render a [`BenchReport`] — the ONE table shape for `pipeit bench` runs
/// and the `cargo bench` micro-benchmark targets (both emit the same
/// artifact). Columns show the robust statistics the regression gate
/// classifies on: median after MAD outlier rejection, and the seeded
/// bootstrap CI of the median.
pub fn render_bench(r: &BenchReport) -> String {
    let mut s = format!(
        "bench suite: {} ({} scenarios)  seed={} reps={} warmup={}\n",
        r.suite,
        r.scenarios.len(),
        r.seed,
        r.reps,
        r.warmup
    );
    let mut t = Table::new(
        "Benchmark results (median / MAD / bootstrap CI after outlier rejection)",
        &["scenario", "mode", "backend", "unit", "n", "median", "ci95", "mad"],
    );
    for sc in &r.scenarios {
        let n = if sc.stats.rejected > 0 {
            format!("{}(-{})", sc.stats.n, sc.stats.rejected)
        } else {
            sc.stats.n.to_string()
        };
        t.row(vec![
            sc.name.clone(),
            sc.mode.clone(),
            sc.backend.clone(),
            sc.unit.clone(),
            n,
            fmt_metric(sc.stats.median, &sc.unit),
            format!(
                "[{}, {}]",
                fmt_metric(sc.stats.ci_lo, &sc.unit),
                fmt_metric(sc.stats.ci_hi, &sc.unit)
            ),
            fmt_metric(sc.stats.mad, &sc.unit),
        ]);
    }
    s.push_str(&t.render());
    s
}

/// Render a [`BenchComparison`] — the `pipeit bench --compare` output.
/// The trailing `verdicts` line is stable and machine-greppable; CI's
/// determinism gate asserts it reads `0 improved, 0 regressed`.
pub fn render_bench_compare(c: &BenchComparison) -> String {
    let mut t = Table::new(
        "Benchmark comparison (CI-overlap classification, not point deltas)",
        &["scenario", "backend", "old median", "new median", "delta", "verdict"],
    );
    for d in &c.diffs {
        t.row(vec![
            d.name.clone(),
            d.backend.clone(),
            fmt_metric(d.old_median, &d.unit),
            fmt_metric(d.new_median, &d.unit),
            format!("{:+.1}%", 100.0 * d.rel_delta),
            d.verdict.to_string(),
        ]);
    }
    let mut s = t.render();
    for a in &c.added {
        s.push_str(&format!("added      : {a} (no baseline)\n"));
    }
    for r in &c.removed {
        s.push_str(&format!("removed    : {r} (not in the new run)\n"));
    }
    s.push_str(&format!(
        "verdicts   : {} improved, {} regressed, {} unchanged\n",
        c.count(Verdict::Improved),
        c.count(Verdict::Regressed),
        c.count(Verdict::Unchanged),
    ));
    s
}

/// Render a [`MetricsSnapshot`] — the observability footer the
/// `serve`-family commands print when tracing is on (DESIGN.md §13): run
/// counters, the pooled `latency` histogram's percentiles (exact within
/// one ~9% bucket), front-door queue-depth peaks, and the hottest stages
/// by occupancy with their service-time histograms (top 8, occupancy
/// descending, key-ordered ties).
pub fn render_metrics(m: &MetricsSnapshot) -> String {
    let mut s = format!(
        "observability: admitted={} shed={} departed={}",
        m.counter("admitted"),
        m.counter("shed"),
        m.counter("departed"),
    );
    if let Some(w) = m.gauge("wall_s") {
        s.push_str(&format!(" wall={w:.3}s"));
    }
    s.push('\n');
    if let Some(h) = m.hist("latency") {
        s.push_str(&format!(
            "latency    : n={} p50={:.1}ms p95={:.1}ms p99={:.1}ms max={:.1}ms\n",
            h.count(),
            h.quantile(50.0) * 1e3,
            h.quantile(95.0) * 1e3,
            h.quantile(99.0) * 1e3,
            h.max() * 1e3,
        ));
    }
    let peaks = m.gauges_with_prefix("queue_depth_peak/");
    if !peaks.is_empty() {
        s.push_str("queue peak :");
        for (k, v) in &peaks {
            s.push_str(&format!(" {}={v:.0}", &k["queue_depth_peak/".len()..]));
        }
        s.push('\n');
    }
    let mut occ = m.gauges_with_prefix("occupancy/");
    occ.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(b.0)));
    if !occ.is_empty() {
        const TOP: usize = 8;
        let mut t = Table::new(
            &format!(
                "Hottest stages by occupancy (top {} of {})",
                occ.len().min(TOP),
                occ.len()
            ),
            &["stage", "occupancy", "items", "p50 ms", "p95 ms", "busy s"],
        );
        for (k, v) in occ.iter().take(TOP) {
            let key = &k["occupancy/".len()..];
            let h = m.hist(&format!("stage_service/{key}"));
            let cell = |x: Option<String>| x.unwrap_or_else(|| "-".to_string());
            t.row(vec![
                key.to_string(),
                format!("{:.1}%", 100.0 * v),
                cell(h.map(|h| h.count().to_string())),
                cell(h.map(|h| format!("{:.1}", h.quantile(50.0) * 1e3))),
                cell(h.map(|h| format!("{:.1}", h.quantile(95.0) * 1e3))),
                cell(h.map(|h| format!("{:.3}", h.sum()))),
            ]);
        }
        s.push_str(&t.render());
    }
    s
}

/// Holds the fitted model + config; memoizes nothing heavier than the fit.
pub struct Reporter {
    pub cfg: Config,
    pub model: PerfModel,
}

/// One Table IV row, kept structured for tests and EXPERIMENTS.md.
#[derive(Debug, Clone)]
pub struct Table4Row {
    pub net: String,
    pub big: f64,
    pub small: f64,
    pub pipeit_measured: f64,
    pub pipeit_predicted: f64,
    pub benefit_pct: f64,
}

impl Reporter {
    pub fn new(cfg: Config) -> Reporter {
        let model = PerfModel::fit(&cfg.platform);
        Reporter { cfg, model }
    }

    fn tm_measured(&self, net: &crate::cnn::Network) -> TimeMatrix {
        TimeMatrix::measured(&self.cfg.platform, net)
    }

    fn tm_predicted(&self, net: &crate::cnn::Network) -> TimeMatrix {
        TimeMatrix::predicted(&self.cfg.platform, &self.model, net)
    }

    fn homogeneous_tp(&self, net: &crate::cnn::Network, core: CoreType) -> f64 {
        let h = self.cfg.platform.cluster(core).cores;
        1.0 / gemm::network_time(&self.cfg.platform, &net.layers, core, h)
    }

    // ---- Table I ----------------------------------------------------------

    pub fn table1(&self) -> Table {
        let mut t = Table::new(
            "Table I: CNN structures (major nodes; paper: 11/58/28/54/26)",
            &["CNN", "Conv", "DwConv", "FC", "Major nodes", "GMACs", "Weights (MB)"],
        );
        for net in zoo::all_networks() {
            let count = |k: LayerKind| net.layers.iter().filter(|l| l.kind == k).count();
            t.row(vec![
                net.name.clone(),
                count(LayerKind::Conv).to_string(),
                count(LayerKind::DwConv).to_string(),
                count(LayerKind::Fc).to_string(),
                net.num_layers().to_string(),
                f(net.total_macs() as f64 / 1e9, 2),
                f(net.total_weight_bytes() as f64 / 1e6, 1),
            ]);
        }
        t
    }

    // ---- Fig. 3 -----------------------------------------------------------

    pub fn fig3(&self) -> Table {
        let mut t = Table::new(
            "Fig. 3: kernel-level throughput vs cores (imgs/s) — rise to 4B, HMP collapse at 4B+1s, partial recovery",
            &["CNN", "1B", "2B", "3B", "4B", "4B1s", "4B2s", "4B3s", "4B4s"],
        );
        for net in zoo::all_networks() {
            let sweep = baselines::core_sweep(&self.cfg.platform, &net);
            let mut row = vec![net.name.clone()];
            row.extend(sweep.iter().map(|p| f(p.throughput, 1)));
            t.row(row);
        }
        t
    }

    // ---- Fig. 4 -----------------------------------------------------------

    pub fn fig4(&self) -> Table {
        let mut t = Table::new(
            "Fig. 4: Big-cluster throughput by framework (imgs/s; TVM lacks GoogLeNet)",
            &["CNN", "ARM-CL", "NCNN", "TVM"],
        );
        for net in zoo::all_networks() {
            let row = baselines::fig4_row(&self.cfg.platform, &net);
            let mut cells = vec![net.name.clone()];
            cells.extend(row.iter().map(|(_, tp)| match tp {
                Some(v) => f(*v, 1),
                None => "-".to_string(),
            }));
            t.row(cells);
        }
        t
    }

    // ---- Fig. 5 -----------------------------------------------------------

    pub fn fig5(&self) -> Table {
        let mut t = Table::new(
            "Fig. 5: disproportionate Big/Small kernel split (throughput normalized to Big-only)",
            &["CNN", "r=0.0", "r=0.25", "r=0.5", "r=0.75", "r=0.9", "r=1.0", "best r", "best"],
        );
        for net in zoo::all_networks() {
            let sweep = baselines::ratio_sweep(&self.cfg.platform, &net, 20);
            let at = |r: f64| {
                sweep
                    .iter()
                    .min_by(|a, b| {
                        (a.0 - r).abs().total_cmp(&(b.0 - r).abs())
                    })
                    .expect("fig5 ratio sweep is empty")
                    .1
            };
            let (best_r, best) = sweep
                .iter()
                .copied()
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .expect("fig5 ratio sweep is empty");
            t.row(vec![
                net.name.clone(),
                f(at(0.0), 2),
                f(at(0.25), 2),
                f(at(0.5), 2),
                f(at(0.75), 2),
                f(at(0.9), 2),
                f(at(1.0), 2),
                f(best_r, 2),
                f(best, 2),
            ]);
        }
        t
    }

    // ---- Fig. 6 -----------------------------------------------------------

    pub fn fig6(&self) -> Table {
        let mut t = Table::new(
            "Fig. 6: share of time in convolutional layers (paper: dominates everywhere except AlexNet)",
            &["CNN", "conv share (%)"],
        );
        for net in zoo::all_networks() {
            let share = baselines::conv_time_share(&self.cfg.platform, &net);
            t.row(vec![net.name.clone(), f(100.0 * share, 1)]);
        }
        t
    }

    // ---- Fig. 7 -----------------------------------------------------------

    pub fn fig7(&self) -> Table {
        let mut t = Table::new(
            "Fig. 7: distribution of conv time over depth (front/mid/back thirds, %)",
            &["CNN", "front", "mid", "back"],
        );
        for net in zoo::all_networks() {
            let d = baselines::layer_time_distribution(&self.cfg.platform, &net);
            let conv: Vec<f64> = net
                .layers
                .iter()
                .zip(&d)
                .filter(|(l, _)| l.kind != LayerKind::Fc)
                .map(|(_, x)| *x)
                .collect();
            let w = conv.len();
            let sum = |r: std::ops::Range<usize>| conv[r].iter().sum::<f64>() * 100.0;
            t.row(vec![
                net.name.clone(),
                f(sum(0..w / 3), 1),
                f(sum(w / 3..w - w / 3), 1),
                f(sum(w - w / 3..w), 1),
            ]);
        }
        t
    }

    // ---- Fig. 8 -----------------------------------------------------------

    pub fn fig8(&self) -> Table {
        let mut t = Table::new(
            "Fig. 8: two-stage (B4-s4) split sweep — optimal split ratio X/W (paper band: 0.60-0.90)",
            &["CNN", "W", "best X", "best ratio", "tp at best", "tp at 0.5", "tp at W-1"],
        );
        let p = dse::PipelineConfig::parse("B4-s4").unwrap();
        for net in zoo::all_networks() {
            let tm = self.tm_measured(&net);
            let sweep = dse::exhaustive::two_stage_sweep(&tm, &p);
            let (bx, btp) = sweep
                .iter()
                .copied()
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .expect("fig8 two-stage sweep is empty");
            let w = tm.num_layers();
            let mid = sweep[w / 2 - 1].1;
            let last = sweep.last().expect("fig8 two-stage sweep is empty").1;
            t.row(vec![
                net.name.clone(),
                w.to_string(),
                bx.to_string(),
                f(bx as f64 / w as f64, 2),
                f(btp, 2),
                f(mid, 2),
                f(last, 2),
            ]);
        }
        t
    }

    // ---- Fig. 9 -----------------------------------------------------------

    pub fn fig9(&self) -> Table {
        let net = zoo::resnet50();
        let tm = self.tm_measured(&net);
        let p3 = dse::PipelineConfig::parse("B4-s2-s2").unwrap();
        let surface = dse::exhaustive::three_stage_surface(&tm, &p3);
        let (x1, x2, tp) = surface
            .iter()
            .copied()
            .max_by(|a, b| a.2.total_cmp(&b.2))
            .expect("fig9 three-stage surface is empty");
        let p2 = dse::PipelineConfig::parse("B4-s4").unwrap();
        let best2 = dse::exhaustive::two_stage_sweep(&tm, &p2)
            .into_iter()
            .map(|(_, v)| v)
            .fold(f64::NEG_INFINITY, f64::max);
        let w = net.num_layers() as f64;
        let mut t = Table::new(
            "Fig. 9: ResNet50 three-stage (B4-s2-s2) split surface peak (paper: peak 5.6 imgs/s at (33,45), +7% over two-stage)",
            &["quantity", "value"],
        );
        t.row(vec!["peak throughput (imgs/s)".into(), f(tp, 2)]);
        t.row(vec!["peak split (X1, X2)".into(), format!("({x1}, {x2})")]);
        t.row(vec![
            "split ratio".into(),
            format!(
                "({:.2}, {:.2}, {:.2})",
                x1 as f64 / w,
                (x2 - x1) as f64 / w,
                (net.num_layers() - x2) as f64 / w
            ),
        ]);
        t.row(vec!["best two-stage (imgs/s)".into(), f(best2, 2)]);
        t.row(vec!["three-stage gain (%)".into(), f(100.0 * (tp / best2 - 1.0), 1)]);
        t
    }

    // ---- Table III --------------------------------------------------------

    pub fn table3(&self) -> Table {
        let mut t = Table::new(
            "Table III: layer-time prediction error (%) per homogeneous core allocation (paper avg: 13.2% Big / 11.4% Small)",
            &["CNN", "1B", "2B", "3B", "4B", "1s", "2s", "3s", "4s"],
        );
        let mut big_all = Vec::new();
        let mut small_all = Vec::new();
        for net in zoo::all_networks() {
            let mut row = vec![net.name.clone()];
            for core in [CoreType::Big, CoreType::Small] {
                for h in 1..=self.cfg.platform.cluster(core).cores {
                    let (mut pred, mut truth) = (Vec::new(), Vec::new());
                    for l in &net.layers {
                        pred.push(self.model.layer_time(l, core, h));
                        truth.push(gemm::layer_time(&self.cfg.platform, l, core, h));
                    }
                    let e = stats::mape(&pred, &truth);
                    match core {
                        CoreType::Big => big_all.push(e),
                        CoreType::Small => small_all.push(e),
                    }
                    row.push(f(e, 1));
                }
            }
            t.row(row);
        }
        t.row(vec![
            "Average".into(),
            "".into(),
            "".into(),
            "".into(),
            format!("{:.1}%", stats::mean(&big_all)),
            "".into(),
            "".into(),
            "".into(),
            format!("{:.1}%", stats::mean(&small_all)),
        ]);
        t
    }

    // ---- Fig. 11 ----------------------------------------------------------

    pub fn fig11(&self) -> Table {
        let mut t = Table::new(
            "Fig. 11: multi-threaded speedup concavity, AlexNet conv layers (Big cluster)",
            &["layer", "1B", "2B", "3B", "4B", "1s", "2s", "3s", "4s"],
        );
        let net = zoo::alexnet();
        for l in net.layers.iter().filter(|l| l.kind == LayerKind::Conv).take(5) {
            let mut row = vec![l.name.clone()];
            for core in [CoreType::Big, CoreType::Small] {
                let t1 = gemm::layer_time(&self.cfg.platform, l, core, 1);
                for h in 1..=4 {
                    row.push(f(t1 / gemm::layer_time(&self.cfg.platform, l, core, h), 2));
                }
            }
            t.row(row);
        }
        t
    }

    // ---- Tables IV/V/VI ---------------------------------------------------

    pub fn table4_rows(&self) -> Vec<Table4Row> {
        zoo::all_networks()
            .into_iter()
            .map(|net| {
                let tm_meas = self.tm_measured(&net);
                let tm_pred = self.tm_predicted(&net);
                let big = self.homogeneous_tp(&net, CoreType::Big);
                let small = self.homogeneous_tp(&net, CoreType::Small);
                let hb = self.cfg.platform.big.cores;
                let hs = self.cfg.platform.small.cores;
                let pt_meas = dse::explore(&tm_meas, hb, hs);
                // Predicted-config point, evaluated on the "board"
                // (measured matrix) — what Table IV's last column reports.
                let pt_pred = dse::explore(&tm_pred, hb, hs);
                let alloc =
                    dse::work_flow(&tm_meas, &pt_pred.pipeline, tm_meas.num_layers());
                let pred_on_board =
                    dse::pipeline_throughput(&tm_meas, &pt_pred.pipeline, &alloc);
                Table4Row {
                    net: net.name.clone(),
                    big,
                    small,
                    pipeit_measured: pt_meas.throughput,
                    pipeit_predicted: pred_on_board,
                    benefit_pct: 100.0 * (pt_meas.throughput / big - 1.0),
                }
            })
            .collect()
    }

    pub fn table4(&self) -> Table {
        let rows = self.table4_rows();
        let mut t = Table::new(
            "Table IV: homogeneous vs Pipe-it throughput (imgs/s; paper avg benefit 39.2%)",
            &["CNN", "Big", "Small", "Pipe-it (measured)", "Pipe-it (predicted)", "Benefit %"],
        );
        for r in &rows {
            t.row(vec![
                r.net.clone(),
                f(r.big, 1),
                f(r.small, 1),
                f(r.pipeit_measured, 1),
                f(r.pipeit_predicted, 1),
                f(r.benefit_pct, 1),
            ]);
        }
        let avg = stats::mean(&rows.iter().map(|r| r.benefit_pct).collect::<Vec<_>>());
        t.row(vec![
            "Average".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            format!("{avg:.1}%"),
        ]);
        t
    }

    fn config_table(&self, title: &str, predicted: bool) -> Table {
        let mut t = Table::new(title, &["CNN", "Pipeline config", "Layer allocation"]);
        for net in zoo::all_networks() {
            let tm = if predicted { self.tm_predicted(&net) } else { self.tm_measured(&net) };
            let pt = dse::explore(&tm, self.cfg.platform.big.cores, self.cfg.platform.small.cores);
            t.row(vec![
                net.name.clone(),
                pt.pipeline.to_string(),
                pt.allocation.display_1based(),
            ]);
        }
        t
    }

    pub fn table5(&self) -> Table {
        self.config_table(
            "Table V: Pipe-it configuration from PREDICTED layer times",
            true,
        )
    }

    pub fn table6(&self) -> Table {
        self.config_table(
            "Table VI: Pipe-it configuration from MEASURED layer times",
            false,
        )
    }

    // ---- Table VII --------------------------------------------------------

    /// Memory intensity of a network on a cluster: memory-ish share of the
    /// execution (drives the power model's mem term).
    fn mem_intensity(&self, net: &crate::cnn::Network) -> f64 {
        // FC-heavy nets stream weights: approximate with weight-bytes per
        // MAC, clamped into [0.3, 0.95].
        let bpm = net.total_weight_bytes() as f64 / net.total_macs() as f64;
        (0.3 + bpm * 3.0).min(0.95)
    }

    pub fn table7(&self) -> Table {
        let mut t = Table::new(
            "Table VII: average active power (W) and efficiency (imgs/J)",
            &["CNN", "P Big", "P Small", "P Pipe-it", "Eff Big", "Eff Small", "Eff Pipe-it"],
        );
        for net in zoo::all_networks() {
            let mem = self.mem_intensity(&net);
            let tp_big = self.homogeneous_tp(&net, CoreType::Big);
            let tp_small = self.homogeneous_tp(&net, CoreType::Small);
            let p_big = self.cfg.power.homogeneous_power(CoreType::Big, 4, mem);
            let p_small = self.cfg.power.homogeneous_power(CoreType::Small, 4, mem);

            let tm = self.tm_measured(&net);
            let pt = dse::explore(&tm, 4, 4);
            let times = dse::point_stage_times(&tm, &pt);
            let bottleneck = times.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            let mut busy_b = 0.0;
            let mut busy_s = 0.0;
            for (stage, time) in pt.pipeline.stages.iter().zip(&times) {
                let util = time / bottleneck;
                match stage.core {
                    CoreType::Big => busy_b += util * stage.count as f64,
                    CoreType::Small => busy_s += util * stage.count as f64,
                }
            }
            let p_pipe = self.cfg.power.active_power(
                ClusterActivity { busy_cores: busy_b, powered: true, mem_intensity: mem },
                ClusterActivity { busy_cores: busy_s, powered: true, mem_intensity: mem },
            );
            t.row(vec![
                net.name.clone(),
                f(p_big, 1),
                f(p_small, 1),
                f(p_pipe, 1),
                f(tp_big / p_big, 1),
                f(tp_small / p_small, 1),
                f(pt.throughput / p_pipe, 1),
            ]);
        }
        t
    }

    // ---- Fig. 13 ----------------------------------------------------------

    pub fn fig13(&self) -> Table {
        let mut t = Table::new(
            "Fig. 13: MobileNet quantization (times normalized to v18.05 F32; Pipe-it latency at +18% gain)",
            &["version", "precision", "conv time", "total time", "Pipe-it latency"],
        );
        for p in baselines::fig13_points() {
            t.row(vec![
                format!("{:?}", p.version),
                if p.quantized { "QASYMM8" } else { "F32" }.to_string(),
                f(p.conv_time, 3),
                f(p.total_time, 3),
                f(baselines::pipeit_latency(&p, 0.18), 3),
            ]);
        }
        t
    }

    // ---- Fig. 14 ----------------------------------------------------------

    pub fn fig14(&self) -> Table {
        let net = zoo::mobilenet();
        let tm = self.tm_measured(&net);
        let pt = dse::explore(&tm, 4, 4);
        // Pipe-it** factor: v18.11+quant overall gain from Fig. 13.
        let pts = baselines::fig13_points();
        let f32_05 = pts
            .iter()
            .find(|p| !p.quantized && matches!(p.version, baselines::ArmClVersion::V1805))
            .expect("fig13 series missing the v18.05 F32 point");
        let q11 = pts
            .iter()
            .find(|p| p.quantized && matches!(p.version, baselines::ArmClVersion::V1811))
            .expect("fig13 series missing the v18.11 QASYMM8 point");
        let quant_factor = f32_05.total_time / q11.total_time;
        let series =
            baselines::fig14_series(&self.cfg.platform, &net, pt.throughput, quant_factor);
        let mut t = Table::new(
            "Fig. 14: MobileNet effective throughput by framework (imgs/s; paper: Pipe-it best, Pipe-it** = 31)",
            &["framework", "throughput"],
        );
        for (name, tp) in series {
            t.row(vec![name, f(tp, 1)]);
        }
        t
    }

    // ---- §VII-E DeepX -----------------------------------------------------

    pub fn deepx(&self) -> Table {
        let net = zoo::alexnet();
        let mem = self.mem_intensity(&net);
        let tm = self.tm_measured(&net);
        let pt = dse::explore(&tm, 4, 4);
        let times = dse::point_stage_times(&tm, &pt);
        let bottleneck = times.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let (mut busy_b, mut busy_s) = (0.0, 0.0);
        for (stage, time) in pt.pipeline.stages.iter().zip(&times) {
            match stage.core {
                CoreType::Big => busy_b += time / bottleneck * stage.count as f64,
                CoreType::Small => busy_s += time / bottleneck * stage.count as f64,
            }
        }
        let p_pipe = self.cfg.power.active_power(
            ClusterActivity { busy_cores: busy_b, powered: true, mem_intensity: mem },
            ClusterActivity { busy_cores: busy_s, powered: true, mem_intensity: mem },
        );
        let d = baselines::deepx_alexnet();
        let mut t = Table::new(
            "§VII-E: AlexNet energy comparison vs DeepX (paper: Pipe-it 1.8 imgs/J at 8.9 imgs/s)",
            &["system", "throughput (imgs/s)", "efficiency (imgs/J)"],
        );
        t.row(vec!["DeepX (SD800)".into(), f(d.throughput, 1), f(d.efficiency_imgs_per_j, 1)]);
        t.row(vec![
            "Pipe-it".into(),
            f(pt.throughput, 1),
            f(pt.throughput / p_pipe, 1),
        ]);
        t
    }

    // ---- Design-space sizes (§IV-B) ----------------------------------------

    pub fn design_space(&self) -> Table {
        let mut t = Table::new(
            "§IV-B design space: 64 pipelines on 4+4; per-CNN design points (Eq. 2)",
            &["CNN", "W", "design points (Eq. 2)", "paper-variant C(W,p-1)"],
        );
        for net in zoo::all_networks() {
            t.row(vec![
                net.name.clone(),
                net.num_layers().to_string(),
                dse::count::design_points(net.num_layers(), 4, 4).to_string(),
                dse::count::design_points_paper_variant(net.num_layers(), 4, 4).to_string(),
            ]);
        }
        t
    }

    // ---- Replicated serving (beyond the paper: PICO-style fleet) ----------

    /// One row per network: the best single pipeline (Eq. 12 + DES) against
    /// the best replicated fleet from [`dse::explore_replicated`]
    /// (aggregate Eq. 12 + replicated DES), with the chosen partition.
    pub fn replicated(&self) -> Table {
        let mut t = Table::new(
            "Replicated serving: best single pipeline vs replicated fleet (imgs/s; R<=4)",
            &["CNN", "single", "single sim", "fleet", "fleet sim", "R", "partition", "gain %"],
        );
        let (hb, hs) = (self.cfg.platform.big.cores, self.cfg.platform.small.cores);
        for net in zoo::all_networks() {
            let tm = self.tm_measured(&net);
            let single = dse::explore(&tm, hb, hs);
            let st = dse::point_stage_times(&tm, &single);
            let single_sim = pipeline_sim::simulate(&st, 1000, 2);
            let fleet = dse::explore_replicated(&tm, hb, hs, 4);
            let fleet_sim =
                pipeline_sim::simulate_replicated(&fleet.stage_times(&tm), 1000, 2);
            t.row(vec![
                net.name.clone(),
                f(single.throughput, 2),
                f(single_sim.throughput, 2),
                f(fleet.throughput, 2),
                f(fleet_sim.throughput, 2),
                fleet.num_replicas().to_string(),
                fleet.partition_display(),
                f(100.0 * (fleet.throughput / single.throughput - 1.0), 1),
            ]);
        }
        t
    }

    /// Ablation: explore vs the paper-literal merge variants, plus the DES
    /// cross-check of Eq. 12 steady-state throughput.
    pub fn ablation(&self) -> Table {
        let mut t = Table::new(
            "Ablation: DSE search variants (imgs/s) + DES check of Eq. 12",
            &["CNN", "explore", "merge (global)", "merge (Eq.14)", "DES sim", "B4 baseline"],
        );
        for net in zoo::all_networks() {
            let tm = self.tm_measured(&net);
            let e = dse::explore(&tm, 4, 4);
            let m = dse::merge_stage(&tm, 4, 4);
            let m14 = dse::merge_stage_eq14(&tm, 4, 4);
            let times = dse::point_stage_times(&tm, &e);
            let sim = pipeline_sim::simulate(&times, 500, 2);
            let b4 = self.homogeneous_tp(&net, CoreType::Big);
            t.row(vec![
                net.name.clone(),
                f(e.throughput, 2),
                f(m.throughput, 2),
                f(m14.throughput, 2),
                f(sim.throughput, 2),
                f(b4, 2),
            ]);
        }
        t
    }

    /// Print every table/figure (CLI `tables`).
    pub fn print_all(&self) {
        self.table1().print();
        self.design_space().print();
        self.fig3().print();
        self.fig4().print();
        self.fig5().print();
        self.fig6().print();
        self.fig7().print();
        self.fig8().print();
        self.fig9().print();
        self.table3().print();
        self.fig11().print();
        self.table4().print();
        self.table5().print();
        self.table6().print();
        self.table7().print();
        self.fig13().print();
        self.fig14().print();
        self.deepx().print();
        self.ablation().print();
        self.replicated().print();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use once_cell::sync::Lazy;

    static REP: Lazy<Reporter> = Lazy::new(|| Reporter::new(Config::default()));

    #[test]
    fn table4_headline_average_benefit() {
        // The paper's headline: +39.2% average over the Big cluster. Our
        // substrate should land in a comparable band (25-70%).
        let rows = REP.table4_rows();
        let avg =
            stats::mean(&rows.iter().map(|r| r.benefit_pct).collect::<Vec<_>>());
        assert!(
            (25.0..70.0).contains(&avg),
            "average benefit {avg:.1}% outside the paper band"
        );
        for r in &rows {
            assert!(
                r.pipeit_measured > r.big.max(r.small),
                "{}: Pipe-it must beat both clusters",
                r.net
            );
            // §VII-B: predicted-config within a few percent of measured.
            assert!(
                r.pipeit_predicted > 0.8 * r.pipeit_measured,
                "{}: predicted {:.2} vs measured {:.2}",
                r.net,
                r.pipeit_predicted,
                r.pipeit_measured
            );
        }
    }

    #[test]
    fn table4_pipeit_near_combined_clusters() {
        // "the throughput obtained through pipelined configuration
        // approaches the combined throughput of the individual clusters."
        let rows = REP.table4_rows();
        for r in &rows {
            let combined = r.big + r.small;
            assert!(
                r.pipeit_measured > 0.85 * combined,
                "{}: {:.2} far below combined {:.2}",
                r.net,
                r.pipeit_measured,
                combined
            );
            assert!(
                r.pipeit_measured < 1.35 * combined,
                "{}: implausibly above combined",
                r.net
            );
        }
    }

    #[test]
    fn all_tables_render() {
        // Every generator must produce non-empty output without panicking.
        for table in [
            REP.table1(),
            REP.design_space(),
            REP.fig3(),
            REP.fig4(),
            REP.fig5(),
            REP.fig6(),
            REP.fig7(),
            REP.fig8(),
            REP.fig9(),
            REP.table3(),
            REP.fig11(),
            REP.table4(),
            REP.table5(),
            REP.table6(),
            REP.table7(),
            REP.fig13(),
            REP.fig14(),
            REP.deepx(),
            REP.ablation(),
            REP.replicated(),
        ] {
            assert!(table.render().lines().count() >= 3);
        }
    }

    #[test]
    fn replicated_fleet_never_loses_and_wins_somewhere() {
        // Acceptance: for at least one network, the replicated design's
        // simulated throughput beats the best single-pipeline design.
        let (hb, hs) = (REP.cfg.platform.big.cores, REP.cfg.platform.small.cores);
        let mut any_sim_gain = false;
        for net in zoo::all_networks() {
            let tm = TimeMatrix::measured(&REP.cfg.platform, &net);
            let single = dse::explore(&tm, hb, hs);
            let st = dse::point_stage_times(&tm, &single);
            let single_sim = pipeline_sim::simulate(&st, 1000, 2);
            let fleet = dse::explore_replicated(&tm, hb, hs, 4);
            let fleet_sim =
                pipeline_sim::simulate_replicated(&fleet.stage_times(&tm), 1000, 2);
            assert!(
                fleet.throughput >= single.throughput - 1e-9,
                "{}: fleet {:.3} lost to single {:.3}",
                net.name,
                fleet.throughput,
                single.throughput
            );
            if fleet.num_replicas() > 1
                && fleet_sim.throughput > single_sim.throughput * 1.001
            {
                any_sim_gain = true;
            }
        }
        assert!(
            any_sim_gain,
            "no network's replicated fleet beat its best single pipeline in the DES"
        );
    }

    #[test]
    fn render_serve_unifies_des_and_fleet_shapes() {
        use crate::api::{PlanSpec, Strategy};
        let plan = PlanSpec::new("alexnet")
            .strategy(Strategy::Replicated { max_replicas: 2, exact: true })
            .compile()
            .unwrap();
        let s = render_serve(&plan.simulate(200, 2).unwrap());
        assert!(s.contains("fleet: 2 replicas"), "{s}");
        assert!(s.contains("aggregate="), "{s}");
        assert!(s.contains("sim tp"), "{s}");
        assert!(s.contains("bottleneck=stage"), "{s}");
        assert!(s.contains("replica 1:"), "{s}");
        assert!(s.contains("latency p50="), "{s}");

        // A single pipeline renders through the SAME shape.
        let single = PlanSpec::new("alexnet").compile().unwrap();
        let s = render_serve(&single.simulate(200, 2).unwrap());
        assert!(s.contains("fleet: 1 replicas"), "{s}");
        assert!(s.contains("replica 0:"), "{s}");
    }

    #[test]
    fn render_multi_serve_unifies_both_backends() {
        use crate::config::Config;
        use crate::tenancy::{MultiPlan, MultiServeOptions, TenantSpec};
        let specs = [
            TenantSpec::new("alexnet", 4.0).with_sla(10.0),
            TenantSpec::new("squeezenet", 8.0),
        ];
        let mp = MultiPlan::compile(&specs, &Config::default(), 2).unwrap();
        let opts = MultiServeOptions { images: 50, ..Default::default() };
        let s = render_multi_serve(&mp.simulate(&opts).unwrap());
        assert!(s.contains("co-serving : 2 tenants"), "{s}");
        assert!(s.contains("(DES)"), "{s}");
        assert!(s.contains("tenant alexnet"), "{s}");
        assert!(s.contains("tenant squeezenet"), "{s}");
        assert!(s.contains("SLAs       : 1/1 met"), "{s}");
        assert!(s.contains("board util"), "{s}");
        assert!(s.contains("SLA p99<=10000ms: OK"), "{s}");
    }

    #[test]
    fn render_cluster_unifies_both_backends_and_marks_down_boards() {
        use crate::cluster::{
            BoardSpec, ClusterPlan, ClusterServeOptions, ClusterSpec, DispatchPolicy,
        };
        use crate::tenancy::TenantSpec;
        let spec = ClusterSpec::new(
            vec![BoardSpec::new(4, 4), BoardSpec::new(2, 6)],
            vec![TenantSpec::new("alexnet", 30.0)],
        );
        let cp = ClusterPlan::compile(&spec, &Config::default()).unwrap();
        let opts = ClusterServeOptions {
            images: 120,
            policy: DispatchPolicy::PowerOfTwo,
            ..Default::default()
        };
        let s = render_cluster(&cp.simulate(&opts).unwrap());
        assert!(s.contains("cluster    : 2 boards"), "{s}");
        assert!(s.contains("(DES)"), "{s}");
        assert!(s.contains("policy     : p2c"), "{s}");
        assert!(s.contains("Σ eq12 capacity"), "{s}");
        assert!(s.contains("board 4+4"), "{s}");
        assert!(s.contains("board 2+6"), "{s}");
        assert!(!s.contains("[down]"), "{s}");

        // A failure drill renders through the SAME shape, with the down
        // board marked and zero-admitted but still listed.
        let drill = ClusterServeOptions {
            disabled: vec!["2+6".into()],
            ..opts
        };
        let s = render_cluster(&cp.simulate(&drill).unwrap());
        assert!(s.contains("[down]"), "{s}");
        assert!(s.contains("admitted=0"), "{s}");
    }

    #[test]
    fn render_metrics_caps_the_stage_table_at_top_8() {
        let rec = crate::obs::Recorder::on();
        for r in 0..5 {
            for st in 0..2 {
                rec.gauge_set(
                    &format!("occupancy/g0r{r}s{st}"),
                    0.05 * (1 + r * 2 + st) as f64,
                );
                rec.observe(&format!("stage_service/g0r{r}s{st}"), 0.01);
            }
        }
        let snap = rec.snapshot().unwrap();
        let s = render_metrics(&snap);
        assert!(s.contains("top 8 of 10"), "{s}");
        // Hottest first; the two coldest stages (r0) fall off the table.
        assert!(s.contains("g0r4s1"), "{s}");
        assert!(!s.contains("g0r0s0 "), "{s}");
        // No latency hist, no queue peaks: those lines are absent.
        assert!(!s.contains("latency"), "{s}");
        assert!(!s.contains("queue peak"), "{s}");
    }

    #[test]
    fn render_metrics_footer_with_fewer_than_8_stages() {
        // The top-8 cap is a cap, not a pad: two stages render "top 2 of 2".
        let rec = crate::obs::Recorder::on();
        for st in 0..2 {
            rec.gauge_set(&format!("occupancy/g0r0s{st}"), 0.4 + 0.1 * st as f64);
            rec.observe(&format!("stage_service/g0r0s{st}"), 0.02);
        }
        let s = render_metrics(&rec.snapshot().unwrap());
        assert!(s.contains("top 2 of 2"), "{s}");
        assert!(s.contains("g0r0s0"), "{s}");
        assert!(s.contains("g0r0s1"), "{s}");
    }

    #[test]
    fn render_metrics_empty_registry_is_one_line() {
        // A fresh registry renders the counter line only: no latency
        // line, no queue peaks, no stage table.
        let s = render_metrics(&MetricsSnapshot::default());
        assert_eq!(s, "observability: admitted=0 shed=0 departed=0\n");
    }

    #[test]
    fn render_attrib_decomposition_table_and_notes() {
        use crate::obs::{attribute, PredictedTimes, Recorder};
        let rec = Recorder::on();
        rec.admit(0, 0, 0.0);
        rec.stage(0, 0, 0, 0, 0.1, 0.3);
        rec.stage(0, 0, 0, 1, 0.5, 0.6);
        rec.depart(0, 0, 0, 0.6);
        rec.shed(0, 1, 0.2);
        let mut pred = PredictedTimes::new();
        pred.insert(0, 0, vec![0.15]); // stage 1 has no prediction
        let mut a = attribute(&rec.spans_sorted(), &pred).expect("conserved");
        a.annotations.push("calibration run".into());
        let s = render_attrib(&a);
        assert!(
            s.contains(
                "attribution: items=1 shed=1  latency 600.0ms = front 100.0ms \
                 + queue 200.0ms + service 300.0ms (means)"
            ),
            "{s}"
        );
        assert!(s.contains("conserved  : max |front+queue+service - latency| = "), "{s}");
        assert!(s.contains("note       : calibration run"), "{s}");
        assert!(s.contains("Eq. 10 prediction"), "{s}");
        // Predicted stage: residual +50ms over 1 item = +0.050s excess.
        assert!(s.contains("g0r0s0"), "{s}");
        assert!(s.contains("+50.00"), "{s}");
        assert!(s.contains("+0.050"), "{s}");
        // Unpredicted stage renders dashes, not zeros.
        assert!(s.contains("g0r0s1"), "{s}");
        assert!(s.contains(" - "), "{s}");
    }

    #[test]
    fn render_serve_appends_attrib_footer_when_recorded() {
        use crate::api::PlanSpec;
        use crate::obs::Recorder;
        let plan = PlanSpec::new("alexnet").compile().unwrap();
        let rec = Recorder::on();
        let r = plan.simulate_recorded(100, 2, &rec).unwrap();
        assert!(r.attrib.is_some(), "recorded DES run must attribute");
        let s = render_serve(&r);
        assert!(s.contains("attribution: items=100"), "{s}");
        assert!(s.contains("conserved  :"), "{s}");
        // The unrecorded path stays footer-free.
        let s = render_serve(&plan.simulate(100, 2).unwrap());
        assert!(!s.contains("attribution:"), "{s}");
    }

    #[test]
    fn render_history_rows_columns_and_deltas() {
        use crate::harness::{BenchHistory, BenchReport, HistoryEntry, SampleStats, ScenarioResult};
        let entry = |name: &str, median: f64| ScenarioResult {
            name: name.into(),
            mode: "pipelined".into(),
            backend: "des".into(),
            unit: "imgs/s".into(),
            higher_is_better: true,
            samples: vec![median; 3],
            stats: SampleStats::from_samples(&[median; 3], 3.5, 0.95, 50, 1),
            host_s: 0.0,
            metrics: None,
        };
        let report = |scenarios: Vec<ScenarioResult>| BenchReport {
            suite: "quick".into(),
            seed: 7,
            warmup: 1,
            reps: 3,
            recorded_rep: None,
            scenarios,
        };
        let h = BenchHistory::from_entries(vec![
            HistoryEntry {
                label: "0".into(),
                report: report(vec![entry("pipelined/alexnet", 16.0), entry("serial/alexnet", 4.5)]),
            },
            HistoryEntry {
                label: "1".into(),
                report: report(vec![entry("pipelined/alexnet", 17.6)]),
            },
        ]);
        let s = render_history(&h);
        assert!(s.contains("bench history: 2 artifacts, 2 scenarios"), "{s}");
        assert!(s.contains("Bench trajectory"), "{s}");
        assert!(s.contains("first->last"), "{s}");
        assert!(s.contains("des/pipelined/alexnet"), "{s}");
        assert!(s.contains("+10.0%"), "{s}");
        // serial/alexnet only appears once: hole and no delta.
        let serial = s
            .lines()
            .find(|l| l.contains("des/serial/alexnet"))
            .expect("serial row");
        assert!(serial.contains("4.50"), "{serial}");
        assert!(serial.matches(" - ").count() >= 2, "hole + no delta: {serial}");
    }

    #[test]
    fn render_bench_and_compare_shapes() {
        use crate::harness::{compare, BenchReport, SampleStats, ScenarioResult};
        let entry = |median: f64, unit: &str, higher: bool| ScenarioResult {
            name: "pipelined/alexnet".into(),
            mode: "pipelined".into(),
            backend: if unit == "s" { "host" } else { "des" }.into(),
            unit: unit.into(),
            higher_is_better: higher,
            samples: vec![median; 3],
            stats: SampleStats::from_samples(&[median; 3], 3.5, 0.95, 50, 1),
            host_s: 0.0,
            metrics: None,
        };
        let report = |m: f64| BenchReport {
            suite: "quick".into(),
            seed: 7,
            warmup: 1,
            reps: 3,
            recorded_rep: None,
            scenarios: vec![entry(m, "imgs/s", true), entry(0.00125, "s", false)],
        };
        let s = render_bench(&report(16.0));
        assert!(s.contains("bench suite: quick (2 scenarios)  seed=7 reps=3 warmup=1"), "{s}");
        assert!(s.contains("16.00"), "{s}");
        assert!(s.contains("1.250e-3"), "time metrics use engineering notation: {s}");

        let c = compare(&report(16.0), &report(14.4), 0.01);
        let s = render_bench_compare(&c);
        assert!(s.contains("-10.0%"), "{s}");
        assert!(s.contains("REGRESSED"), "{s}");
        // One regression (throughput down 10%); the time-like entry is
        // unchanged (same samples both sides).
        assert!(s.contains("verdicts   : 0 improved, 1 regressed, 1 unchanged"), "{s}");
    }

    #[test]
    fn table7_power_bands() {
        let t = REP.table7().render();
        // Sanity: table renders with all five nets.
        for n in ["alexnet", "googlenet", "mobilenet", "resnet50", "squeezenet"] {
            assert!(t.contains(n));
        }
    }
}
