//! `cargo bench --bench paper_tables` — regenerates every TABLE of the
//! paper's evaluation (I, III, IV, V, VI, VII + §IV-B design-space sizes +
//! §VII-E DeepX), printing paper-vs-ours, and times the generating code
//! paths with the in-tree bench harness.

use pipeit::config::Config;
use pipeit::harness::{black_box, HostBench};
use pipeit::reports::Reporter;

fn main() {
    let rep = Reporter::new(Config::default());

    println!("================ PAPER TABLES (reproduced) ================\n");
    rep.table1().print();
    println!("paper Table I major nodes: alexnet 11, googlenet 58, mobilenet 28, resnet50 54, squeezenet 26\n");

    rep.design_space().print();
    println!("paper §IV-B: 64 pipelines on 4+4; MobileNet \"5,379,616\" (matches the C(W,p-1) variant)\n");

    rep.table3().print();
    println!("paper Table III averages: 13.2% (Big), 11.4% (Small)\n");

    rep.table4().print();
    println!("paper Table IV: AlexNet 8.1/1.5/8.9 (+9.8%), GoogLeNet 7.8/3.3/11.8 (+45.5%), MobileNet 17.4/6.6/24.0 (+35.5%), ResNet50 3.1/1.5/5.5 (+67.5%), SqueezeNet 15.6/6.9/21.4 (+37.5%); avg +39.2%\n");

    rep.table5().print();
    println!("paper Table V: AlexNet B4-s4 [1,9]-[10,11]; GoogLeNet B4-s2-s1-s1; MobileNet B2-B2-s3-s1; ResNet50 B4-s2-s2 [1,35]-[36,44]-[45,54]; SqueezeNet B4-s4\n");

    rep.table6().print();
    println!("paper Table VI: measured-time configs (AlexNet B4-s4 [1,9]-[10,11], ResNet50 B2-B2-s3-s1, ...)\n");

    rep.table7().print();
    println!("paper Table VII: Big 3.8-4.9 W, Small 0.7-1.3 W, Pipe-it 5.1-6.9 W; Pipe-it efficiency ~= Big-cluster level\n");

    rep.deepx().print();
    println!("paper §VII-E: DeepX 2.2 imgs/J @ 2 imgs/s vs Pipe-it 1.8 imgs/J @ 8.9 imgs/s\n");

    rep.ablation().print();

    println!("================ timing the generators ================\n");
    let mut b = HostBench::new();
    b.time("table4_full_dse_all_nets", || {
        black_box(rep.table4_rows());
    });
    b.time("table3_prediction_error", || {
        black_box(rep.table3());
    });
    b.time("table7_power_model", || {
        black_box(rep.table7());
    });

    b.finish("paper_tables").expect("bench epilogue");
}
