//! `cargo bench --bench fleet` — replicated-pipeline serving benchmarks,
//! as a thin wrapper over the in-tree harness ([`pipeit::harness`]):
//!
//!   * the replicated DSE (core partitions x per-budget pipelines) per CNN
//!   * the fleet discrete-event simulation at stream scale
//!   * the dispatcher hot path of the real thread fleet (no stage work)
//!
//! Also prints the replicated-vs-single report table, so `cargo bench`
//! output shows where replication pays (the PICO-style scaling story).
//! Set `BENCH_OUT=file.json` to capture the run as a comparable artifact.

use pipeit::cnn::zoo;
use pipeit::config::Config;
use pipeit::coordinator::{run_fleet, StageSpec};
use pipeit::dse;
use pipeit::harness::{black_box, HostBench};
use pipeit::perfmodel::TimeMatrix;
use pipeit::reports::Reporter;
use pipeit::simulator::pipeline_sim;

fn noop_replica(stages: usize) -> Vec<StageSpec<u64>> {
    (0..stages)
        .map(|s| {
            StageSpec::new(
                &format!("s{s}"),
                Box::new(|| Box::new(|x: u64| x.wrapping_mul(0x9E37_79B9))),
            )
        })
        .collect()
}

fn main() {
    let cfg = Config::default();

    println!("================ REPLICATED SERVING (fleet) ================\n");
    Reporter::new(Config::default()).replicated().print();

    let mut b = HostBench::new();
    let nets = zoo::all_networks();
    let tms: Vec<TimeMatrix> =
        nets.iter().map(|n| TimeMatrix::measured(&cfg.platform, n)).collect();

    for (net, tm) in nets.iter().zip(&tms) {
        b.time(&format!("explore_replicated_r4_{}", net.name), || {
            black_box(dse::explore_replicated(tm, 4, 4, 4));
        });
    }

    let fleet = dse::explore_replicated(&tms[3], 4, 4, 4); // resnet50
    let times = fleet.stage_times(&tms[3]);
    b.time("fleet_des_10k_images_resnet50", || {
        black_box(pipeline_sim::simulate_replicated(&times, 10_000, 2));
    });

    b.time("partitions_enumeration_4_4_r4", || {
        black_box(dse::replicated::partitions(4, 4, 4));
    });

    // Dispatcher hot path: 2 replicas x 2 no-op stages, 512 items per
    // iteration — measures admission + least-outstanding-work routing +
    // thread fleet setup/teardown, not stage compute.
    let mut quick = HostBench::quick();
    quick.time("run_fleet_dispatch_2x2_512_items", || {
        let replicas = vec![noop_replica(2), noop_replica(2)];
        let (out, _) = run_fleet(replicas, 2, 4, 0..512u64);
        black_box(out);
    });

    b.results.extend(quick.results);
    b.finish("fleet").expect("bench epilogue");

    println!("\nnote: the replicated DSE spans every core partition (R<=4) of the");
    println!("4+4 budget and still completes in milliseconds per network.");
}
