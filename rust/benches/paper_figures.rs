//! `cargo bench --bench paper_figures` — regenerates every FIGURE series of
//! the paper's evaluation (3, 4, 5, 6, 7, 8, 9, 11, 13, 14), printing the
//! same rows/series the paper plots, and times the generating sweeps.

use pipeit::config::Config;
use pipeit::harness::{black_box, HostBench};
use pipeit::reports::Reporter;
use pipeit::{baselines, cnn::zoo};

fn main() {
    let rep = Reporter::new(Config::default());

    println!("================ PAPER FIGURES (reproduced) ================\n");
    rep.fig3().print();
    println!("paper Fig. 3 shape: rises to 4B, collapses at 4B+1s, partial recovery never above 4B\n");

    rep.fig4().print();
    println!("paper Fig. 4: ARM-CL ~ NCNN >> TVM (no NEON); GoogLeNet absent for TVM\n");

    rep.fig5().print();
    println!("paper Fig. 5: no split ratio significantly beats Big-only (best ~= r=1.0)\n");

    rep.fig6().print();
    println!("paper Fig. 6: conv dominates everywhere except AlexNet (FC-heavy)\n");

    rep.fig7().print();
    println!("paper Fig. 7: conv time generally decreases with depth\n");

    rep.fig8().print();
    println!("paper Fig. 8: optimal two-stage split ratio 0.60 (GoogLeNet) .. 0.90 (AlexNet)\n");

    rep.fig9().print();
    println!("paper Fig. 9: ResNet50 B4-s2-s2 peak 5.6 imgs/s at split (33,45), ratio (0.61,0.22,0.17), +7% over two-stage\n");

    rep.fig11().print();
    println!("paper Fig. 11: concave speedups (diminishing returns per added core)\n");

    rep.fig13().print();
    println!("paper Fig. 13: v18.05 quant: conv -14%, overall flat; v18.11: F32 -20%, quant conv -24%, overall -19%; Pipe-it** reaches 31 imgs/s\n");

    rep.fig14().print();
    println!("paper Fig. 14: Pipe-it best-in-class for MobileNet; Pipe-it** = 31 imgs/s\n");

    println!("================ timing the sweeps ================\n");
    let cfg = Config::default();
    let nets = zoo::all_networks();
    let mut b = HostBench::new();
    b.time("fig3_core_sweep_all_nets", || {
        for net in &nets {
            black_box(baselines::core_sweep(&cfg.platform, net));
        }
    });
    b.time("fig5_ratio_sweep_all_nets", || {
        for net in &nets {
            black_box(baselines::ratio_sweep(&cfg.platform, net, 20));
        }
    });
    b.time("fig8_two_stage_sweeps", || {
        black_box(rep.fig8());
    });
    b.time("fig9_resnet_surface", || {
        black_box(rep.fig9());
    });

    b.finish("paper_figures").expect("bench epilogue");
}
