//! `cargo bench --bench hotpath` — micro-benchmarks of the L3 hot paths,
//! as a thin wrapper over the in-tree harness ([`pipeit::harness`]):
//!
//!   * perfmodel fit (one-time cost, paper's alternative is hours on-board)
//!   * time-matrix construction
//!   * work_flow allocation and the full explore DSE
//!   * discrete-event pipeline simulation
//!   * bounded-queue hot path (send/recv cycle)
//!
//! Paper context: exhaustive search is "hundreds of days"; Pipe-it's whole
//! point is that the DSE is effectively free. These benches quantify that,
//! with the harness's robust statistics (median / MAD rejection /
//! bootstrap CI). Set `BENCH_OUT=file.json` to capture the run as a
//! `BENCH_<n>.json` artifact comparable via `pipeit bench --compare`.

use pipeit::cnn::zoo;
use pipeit::config::Config;
use pipeit::coordinator::queue;
use pipeit::dse;
use pipeit::harness::{black_box, HostBench};
use pipeit::perfmodel::{PerfModel, TimeMatrix};
use pipeit::simulator::pipeline_sim;

fn main() {
    let cfg = Config::default();
    let mut b = HostBench::new();

    b.time("perfmodel_fit_both_clusters", || {
        black_box(PerfModel::fit(&cfg.platform));
    });

    let model = PerfModel::fit(&cfg.platform);
    let nets = zoo::all_networks();

    for net in &nets {
        b.time(&format!("time_matrix_predicted_{}", net.name), || {
            black_box(TimeMatrix::predicted(&cfg.platform, &model, net));
        });
    }

    let tms: Vec<TimeMatrix> =
        nets.iter().map(|n| TimeMatrix::measured(&cfg.platform, n)).collect();

    for (net, tm) in nets.iter().zip(&tms) {
        b.time(&format!("work_flow_B4s2s2_{}", net.name), || {
            let p = dse::PipelineConfig::parse("B4-s2-s2").unwrap();
            black_box(dse::work_flow(tm, &p, tm.num_layers()));
        });
    }

    for (net, tm) in nets.iter().zip(&tms) {
        b.time(&format!("explore_64_pipelines_{}", net.name), || {
            black_box(dse::explore(tm, 4, 4));
        });
    }

    b.time("merge_stage_eq14_resnet50", || {
        black_box(dse::merge_stage_eq14(&tms[3], 4, 4));
    });

    b.time("des_simulate_3stage_10k_images", || {
        black_box(pipeline_sim::simulate(&[0.03, 0.05, 0.02], 10_000, 2));
    });

    b.time("bounded_queue_send_recv_1k", || {
        let (tx, rx) = queue::bounded(64);
        for i in 0..1000u32 {
            tx.send(i).unwrap();
            if i % 32 == 31 {
                while rx.try_recv().is_some() {}
            }
        }
        while rx.try_recv().is_some() {}
        black_box(());
    });

    b.time("exhaustive_two_stage_alexnet", || {
        let p = dse::PipelineConfig::parse("B4-s4").unwrap();
        black_box(dse::exhaustive::best_allocation(&tms[0], &p));
    });

    b.finish("hotpath").expect("bench epilogue");

    println!("\nnote: the paper estimates exhaustive search at hundreds of days;");
    println!("explore() above covers the same pipeline space in microseconds-milliseconds.");
}
