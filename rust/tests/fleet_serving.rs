//! Integration: replicated-fleet serving across the whole framework — the
//! replicated DSE feeds the REAL thread fleet, whose wall-clock behavior is
//! checked against the replicated discrete-event simulation (no artifacts
//! required).

use pipeit::cnn::zoo;
use pipeit::coordinator::{run_fleet, synthetic_fleet};
use pipeit::dse;
use pipeit::perfmodel::TimeMatrix;
use pipeit::simulator::pipeline_sim;
use pipeit::simulator::platform::Platform;

#[test]
fn real_fleet_tracks_replicated_des_on_synthetic_stages() {
    // Heterogeneous replicas: a fast 2-stage pipe and a slow single stage.
    let times = vec![vec![0.004, 0.004], vec![0.009]];
    let images = 120;
    let (_, report) = run_fleet(synthetic_fleet(&times, 1.0), 2, 4, 0..images);
    let sim = pipeline_sim::simulate_replicated(&times, images, 2);
    assert_eq!(report.images, images);
    let rel = (report.throughput() - sim.throughput).abs() / sim.throughput;
    assert!(
        rel < 0.35,
        "real fleet {:.1} imgs/s vs DES {:.1} (rel {rel:.2})",
        report.throughput(),
        sim.throughput
    );
    // The faster replica must carry more of the stream in both worlds.
    assert!(report.dispatched[0] > report.dispatched[1], "{:?}", report.dispatched);
    assert!(sim.dispatched[0] > sim.dispatched[1], "{:?}", sim.dispatched);
}

#[test]
fn dse_chosen_fleet_serves_end_to_end() {
    // explore_exact -> stage times -> real thread fleet, scaled down so the
    // test stays fast. Every image must come out, spread over both replicas.
    let platform = Platform::hikey970();
    let tm = TimeMatrix::measured(&platform, &zoo::by_name("alexnet").unwrap());
    let design = dse::explore_exact(&tm, 4, 4, 2).expect("2-replica design exists");
    assert_eq!(design.num_replicas(), 2);

    let images = 40;
    let (out, report) =
        run_fleet(synthetic_fleet(&design.stage_times(&tm), 0.02), 2, 4, 0..images);
    assert_eq!(out.len(), images);
    assert_eq!(report.images, images);
    assert!(report.dispatched.iter().all(|&d| d > 0), "{:?}", report.dispatched);
    assert_eq!(report.latencies.count(), images);
}

#[test]
fn replicated_design_beats_single_pipeline_wall_clock_for_alexnet() {
    // The tentpole claim, end to end on the real executor: the chosen
    // replicated fleet outruns the best single pipeline on the same
    // (scaled) service times. Generous margin — shared CI hosts.
    let platform = Platform::hikey970();
    let tm = TimeMatrix::measured(&platform, &zoo::by_name("alexnet").unwrap());
    let single = dse::explore(&tm, 4, 4);
    let fleet = dse::explore_replicated(&tm, 4, 4, 4);
    if fleet.num_replicas() < 2 || fleet.throughput <= single.throughput * 1.08 {
        // Substrate calibration may make the single pipeline win for this
        // net; the cross-net guarantee lives in reports::tests.
        eprintln!("skipping wall-clock race: replication gain too small on alexnet");
        return;
    }

    let scale = 0.05;
    let images = 60;
    let (_, fleet_rep) = run_fleet(
        synthetic_fleet(&fleet.stage_times(&tm), scale),
        2,
        4,
        0..images,
    );
    let single_times = vec![dse::point_stage_times(&tm, &single)];
    let (_, single_rep) =
        run_fleet(synthetic_fleet(&single_times, scale), 2, 1, 0..images);
    assert!(
        fleet_rep.wall.as_secs_f64() < single_rep.wall.as_secs_f64(),
        "fleet {:?} should beat single pipeline {:?}",
        fleet_rep.wall,
        single_rep.wall
    );
}

#[test]
fn fleet_report_merges_replica_latencies() {
    let times = vec![vec![0.003], vec![0.003]];
    let images = 30;
    let (_, report) = run_fleet(synthetic_fleet(&times, 1.0), 1, 2, 0..images);
    assert_eq!(report.latencies.count(), images);
    // Each latency is at least one service time.
    assert!(report.latencies.p50() >= 0.003 - 1e-9);
    let per_replica: usize = report.replicas.iter().map(|r| r.latencies.count()).sum();
    assert_eq!(per_replica, images);
}
