//! Differential suite for the shared DES event core (DESIGN.md §15).
//!
//! The event-core rewrite replaced the full-history recurrences inside
//! all three DES engines with bounded rings + an admission heap. The
//! contract is bit-identity: at the same seed, the fast engines must
//! produce byte-identical reports and traces to the retained reference
//! recurrences. This suite enforces that contract on the registry's own
//! plans and arrival streams (not just synthetic fixtures), pins the
//! seed-stream derivation audited alongside the rewrite, and asserts
//! that the front door's scan work stays linear in events — the O(n²)
//! regression this PR fixed must fail a test, not a profile review.

use std::collections::HashSet;

use pipeit::api::{PlanSpec, Strategy};
use pipeit::cluster::{
    simulate_cluster_streams_recorded, ClusterServeOptions, DispatchPolicy,
};
use pipeit::config::Config;
use pipeit::harness::{registry, Backend};
use pipeit::obs::Recorder;
use pipeit::simulator::pipeline_sim::{
    simulate_disturbed_recorded, simulate_disturbed_reference, ThrottleEvent,
};
use pipeit::simulator::{poisson_arrivals, simulate, simulate_stationary};
use pipeit::tenancy::cosim::{
    simulate_tenant_fleet_recorded, simulate_tenant_fleet_reference_recorded,
};
use pipeit::tenancy::{MultiPlan, MultiServeOptions, TenantSpec};

/// The registry's multi-tenant mix, reproduced here so the differential
/// runs on the same plans and arrival streams the harness benches.
fn registry_mix() -> (MultiPlan, MultiServeOptions) {
    let specs =
        vec![TenantSpec::new("alexnet", 30.0), TenantSpec::new("squeezenet", 60.0)];
    let mp = MultiPlan::compile(&specs, &Config::default(), 2).expect("registry mix compiles");
    let opts = MultiServeOptions { images: 120, ..Default::default() };
    (mp, opts)
}

#[test]
fn tenancy_fast_engine_is_bit_identical_to_the_reference_on_the_registry_mix() {
    let (mp, opts) = registry_mix();
    for (i, t) in mp.tenants.iter().enumerate() {
        let arrivals =
            poisson_arrivals(t.rate_hz, opts.images, opts.tenant_seed(t.seed, i));
        let stage_times: Vec<Vec<f64>> =
            t.plan.replicas.iter().map(|r| r.stage_times.clone()).collect();
        let (rec_fast, rec_ref) = (Recorder::on(), Recorder::on());
        let fast = simulate_tenant_fleet_recorded(
            &stage_times,
            &arrivals,
            opts.queue_cap,
            opts.admission_cap,
            &rec_fast,
            i as u32,
        );
        let reference = simulate_tenant_fleet_reference_recorded(
            &stage_times,
            &arrivals,
            opts.queue_cap,
            opts.admission_cap,
            &rec_ref,
            i as u32,
        );
        assert_eq!(fast.offered, reference.offered, "tenant {i}");
        assert_eq!(fast.admitted, reference.admitted, "tenant {i}");
        assert_eq!(fast.shed, reference.shed, "tenant {i}");
        assert_eq!(fast.dispatched, reference.dispatched, "tenant {i}");
        assert_eq!(
            fast.makespan.to_bits(),
            reference.makespan.to_bits(),
            "tenant {i}: makespan drifted"
        );
        assert_eq!(fast.latencies.len(), reference.latencies.len(), "tenant {i}");
        for (k, (a, b)) in
            fast.latencies.iter().zip(&reference.latencies).enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "tenant {i}: latency {k} drifted");
        }
        assert_eq!(
            format!("{:?}", fast.busy),
            format!("{:?}", reference.busy),
            "tenant {i}: busy-seconds drifted"
        );
        // Trace-level identity: the same admit → stage → depart / shed
        // chains at the same simulated times, span for span.
        assert_eq!(
            format!("{:?}", rec_fast.spans_sorted()),
            format!("{:?}", rec_ref.spans_sorted()),
            "tenant {i}: span streams differ"
        );
        // And the fix itself: the reference front door does quadratic scan
        // work, the event core pops each admitted start exactly once.
        assert!(
            fast.scan_iters <= fast.admitted as u64,
            "tenant {i}: front door is no longer O(log n) per arrival"
        );
        assert!(
            reference.scan_iters >= fast.scan_iters,
            "tenant {i}: reference should do at least as much scan work"
        );
    }
}

#[test]
fn pipeline_ring_engine_is_bit_identical_to_the_reference_on_registry_plans() {
    for net in ["alexnet", "squeezenet"] {
        let plan = PlanSpec::new(net)
            .platform(Config::default())
            .strategy(Strategy::Pipeline)
            .compile()
            .expect("pipeline plan compiles");
        let stage_times = &plan.replicas[0].stage_times;
        // A disturbance script with machine-wide and scoped events, plus a
        // non-zero t0: every branch of the factor timeline is exercised.
        let events = vec![
            ThrottleEvent { at: 5.0, factor: 1.5, scope: vec![] },
            ThrottleEvent { at: 9.0, factor: 0.8, scope: vec![(0, 1)] },
            ThrottleEvent { at: 2.0, factor: 1.1, scope: vec![(0, 0)] },
        ];
        let (rec_fast, rec_ref) = (Recorder::on(), Recorder::on());
        let mut svc_fast = Vec::new();
        let mut svc_ref = Vec::new();
        let fast = simulate_disturbed_recorded(
            stage_times,
            200,
            2,
            &events,
            2.5,
            0,
            &rec_fast,
            0,
            None,
            |s, t| svc_fast.push((s, t.to_bits())),
        );
        let reference = simulate_disturbed_reference(
            stage_times,
            200,
            2,
            &events,
            2.5,
            0,
            &rec_ref,
            0,
            None,
            |s, t| svc_ref.push((s, t.to_bits())),
        );
        assert_eq!(fast.makespan.to_bits(), reference.makespan.to_bits(), "{net}");
        assert_eq!(fast.throughput.to_bits(), reference.throughput.to_bits(), "{net}");
        assert_eq!(fast.bottleneck, reference.bottleneck, "{net}");
        assert_eq!(fast.latencies.len(), reference.latencies.len(), "{net}");
        for (k, (a, b)) in
            fast.latencies.iter().zip(&reference.latencies).enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "{net}: latency {k} drifted");
        }
        for (k, (a, b)) in
            fast.utilization.iter().zip(&reference.utilization).enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "{net}: utilization {k} drifted");
        }
        assert_eq!(svc_fast, svc_ref, "{net}: on_service callback streams differ");
        assert_eq!(
            format!("{:?}", rec_fast.spans_sorted()),
            format!("{:?}", rec_ref.spans_sorted()),
            "{net}: span streams differ"
        );
    }
}

#[test]
fn cluster_engine_matches_the_tenancy_engine_on_a_single_board() {
    // A one-board, one-workload cluster is exactly one tenant fleet behind
    // the same front door: outcome fields and span streams must agree
    // bitwise. This anchors the cluster engine to the differential pair
    // above (it shares the event core but has no retained twin of its own).
    let replicas = vec![vec![0.010, 0.014, 0.008], vec![0.012, 0.012, 0.012]];
    let arrivals = poisson_arrivals(120.0, 400, 7);
    let merged: Vec<(f64, usize)> = arrivals.iter().map(|&t| (t, 0)).collect();
    let (rec_cluster, rec_tenant) = (Recorder::on(), Recorder::on());
    let boards = simulate_cluster_streams_recorded(
        &[vec![replicas.clone()]],
        &[1.0],
        &[true],
        &merged,
        DispatchPolicy::RoundRobin,
        2,
        8,
        7,
        &rec_cluster,
    )
    .expect("single-board cluster runs");
    let tenant =
        simulate_tenant_fleet_recorded(&replicas, &arrivals, 2, 8, &rec_tenant, 0);
    assert_eq!(boards.len(), 1);
    let b = &boards[0];
    assert_eq!(b.offered, tenant.offered);
    assert_eq!(b.admitted, tenant.admitted);
    assert_eq!(b.shed, tenant.shed);
    assert_eq!(b.makespan.to_bits(), tenant.makespan.to_bits());
    assert_eq!(b.latencies.len(), tenant.latencies.len());
    for (k, (a, t)) in b.latencies.iter().zip(&tenant.latencies).enumerate() {
        assert_eq!(a.to_bits(), t.to_bits(), "latency {k} drifted");
    }
    assert_eq!(b.dispatched[0], tenant.dispatched);
    assert_eq!(
        format!("{:?}", rec_cluster.spans_sorted()),
        format!("{:?}", rec_tenant.spans_sorted()),
        "cluster and tenancy span streams differ on the degenerate cluster"
    );
}

#[test]
fn every_wall_free_registry_scenario_is_bit_deterministic_and_recording_invariant() {
    // Byte-identical reports at the same seed, with or without the
    // recorder: the harness-level face of the bit-identity contract
    // (recorded runs add only `prof/*` metrics, which live beside the
    // report, never inside it).
    for s in registry() {
        if s.des_only {
            continue; // exercised at reduced size below (1M items in debug)
        }
        let m1 = s.run(Backend::Des, 7).expect("DES run");
        let m2 = s.run(Backend::Des, 7).expect("DES rerun");
        let (m3, snap) =
            s.run_recorded(Backend::Des, 7, &Recorder::on()).expect("recorded run");
        assert_eq!(m1.to_bits(), m2.to_bits(), "{}: not deterministic", s.name);
        assert_eq!(m1.to_bits(), m3.to_bits(), "{}: recorder changed the metric", s.name);
        if s.mode == "multi-tenant" {
            let snap = snap.expect("multi-tenant runs embed a snapshot");
            assert!(
                snap.counter("prof/tenancy/events") > 0,
                "{}: engine profile missing",
                s.name
            );
        }
    }
}

#[test]
fn hot_scenario_front_door_scan_work_is_linear_in_events() {
    // The stress entry itself carries 2×500k arrivals — sized for the
    // release-mode bench where the events/s headline is recorded. Here
    // (debug, under `cargo test`) run the same scenario at reduced volume:
    // the linearity bound is scale-free, so any O(n²) regression still
    // trips it, cheaply.
    let mut s = registry()
        .into_iter()
        .find(|s| s.name == "multi/hot-2x500k")
        .expect("stress scenario registered");
    assert!(s.des_only && s.images >= 500_000);
    s.images = 20_000;
    let (metric, snap) =
        s.run_recorded(Backend::Des, 7, &Recorder::on()).expect("stress run");
    assert!(metric > 0.0);
    let snap = snap.expect("recorded run embeds a snapshot");
    let events = snap.counter("prof/tenancy/events");
    let scans = snap.counter("prof/tenancy/scan_iters");
    assert!(events >= 40_000, "expected ≥ 2×20k arrivals of events, got {events}");
    assert!(
        scans <= events,
        "front door scan work regressed to superlinear: {scans} scans for {events} events"
    );
    assert!(
        snap.gauge("prof/tenancy/events_per_s").unwrap_or(0.0) > 0.0,
        "events/s headline gauge missing"
    );
}

#[test]
fn seed_streams_for_reps_tenants_boards_and_workloads_are_pairwise_disjoint() {
    // The audited derivation (DESIGN.md §15): harness reps add `+r`
    // (r < 7919, enforced by the runner), tenants/boards add `+7919·i`,
    // cluster workloads add `+7919²·t` — mixed-radix digits, so every
    // (rep, index, workload) triple draws a distinct SplitMix64 stream.
    let m_opts = MultiServeOptions::default();
    let c_opts = ClusterServeOptions::default();
    assert_eq!(m_opts.seed, c_opts.seed, "backends share the base seed");
    let mut seen = HashSet::new();
    for rep in 0u64..32 {
        for idx in 0..16 {
            let base = MultiServeOptions { seed: m_opts.seed + rep, ..m_opts };
            let tenant = base.tenant_seed(None, idx);
            let board =
                ClusterServeOptions { seed: c_opts.seed + rep, ..c_opts.clone() }
                    .board_seed(None, idx);
            assert_eq!(tenant, board, "tenant and board derivations diverged");
            for workload in 0u64..8 {
                // 7919² is `cluster::cosim::WORKLOAD_SEED_STRIDE` (crate
                // private); the literal pins the published scheme.
                let stream = board.wrapping_add(7919 * 7919 * workload);
                assert!(
                    seen.insert(stream),
                    "seed collision at rep {rep}, index {idx}, workload {workload}"
                );
            }
        }
    }
    assert_eq!(seen.len(), 32 * 16 * 8);
}

#[test]
fn stationary_fast_path_is_exact_via_the_public_api() {
    // Dyadic stage times: the analytic continuation is exactly
    // representable, so the fast path must agree bitwise with stepping.
    let times = [0.25, 0.375, 0.25];
    let stepped = simulate(&times, 4000, 2);
    let (fast, engaged) = simulate_stationary(&times, 4000, 2);
    assert!(engaged.is_some(), "constant service times must reach stationarity");
    assert_eq!(fast.makespan.to_bits(), stepped.makespan.to_bits());
    assert_eq!(fast.throughput.to_bits(), stepped.throughput.to_bits());
    assert_eq!(fast.latencies.len(), stepped.latencies.len());
    for (k, (a, b)) in fast.latencies.iter().zip(&stepped.latencies).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "latency {k} drifted");
    }
    for (k, (a, b)) in
        fast.utilization.iter().zip(&stepped.utilization).enumerate()
    {
        assert_eq!(a.to_bits(), b.to_bits(), "utilization {k} drifted");
    }
}
