//! Integration: perfmodel -> DSE -> discrete-event simulator consistency
//! across the whole framework (no artifacts required).

use pipeit::cnn::zoo;
use pipeit::config::Config;
use pipeit::dse;
use pipeit::perfmodel::{PerfModel, TimeMatrix};
use pipeit::simulator::{pipeline_sim, CoreType};

#[test]
fn dse_point_survives_des_simulation() {
    // For every network: the Eq. 12 throughput of the chosen design point
    // must match the discrete-event simulation within 2% at 1000 images.
    let cfg = Config::default();
    for net in zoo::all_networks() {
        let tm = TimeMatrix::measured(&cfg.platform, &net);
        let pt = dse::explore(&tm, 4, 4);
        let times = dse::point_stage_times(&tm, &pt);
        let sim = pipeline_sim::simulate(&times, 1000, 2);
        let rel = (sim.throughput - pt.throughput).abs() / pt.throughput;
        assert!(rel < 0.02, "{}: eq12 {} vs sim {}", net.name, pt.throughput, sim.throughput);
    }
}

#[test]
fn predicted_and_measured_dse_agree_on_shape() {
    // Predicted-time DSE must pick a config whose *measured* performance
    // still beats both homogeneous clusters (the paper's end-to-end story).
    let cfg = Config::default();
    let model = PerfModel::fit(&cfg.platform);
    for net in zoo::all_networks() {
        let tm_pred = TimeMatrix::predicted(&cfg.platform, &model, &net);
        let tm_meas = TimeMatrix::measured(&cfg.platform, &net);
        let pt = dse::explore(&tm_pred, 4, 4);
        let alloc = dse::work_flow(&tm_meas, &pt.pipeline, tm_meas.num_layers());
        let tp = dse::pipeline_throughput(&tm_meas, &pt.pipeline, &alloc);
        let b4 = tm_meas.config_index(CoreType::Big, 4).unwrap();
        let s4 = tm_meas.config_index(CoreType::Small, 4).unwrap();
        let tp_b4 = 1.0 / tm_meas.range(0, tm_meas.num_layers(), b4);
        let tp_s4 = 1.0 / tm_meas.range(0, tm_meas.num_layers(), s4);
        assert!(
            tp > tp_b4.max(tp_s4),
            "{}: predicted-config tp {tp:.2} vs B4 {tp_b4:.2} / s4 {tp_s4:.2}",
            net.name
        );
    }
}

#[test]
fn platform_retargeting_changes_design_points() {
    // The config system must actually retarget the DSE: an asymmetric
    // 2-big/6-small platform must produce valid (and generally different)
    // pipelines within its core budget.
    let cfg =
        Config::load(std::path::Path::new("configs/asymmetric_2big_6small.json")).unwrap();
    assert_eq!(cfg.platform.big.cores, 2);
    assert_eq!(cfg.platform.small.cores, 6);
    for net in zoo::all_networks() {
        let tm = TimeMatrix::measured(&cfg.platform, &net);
        let pt = dse::explore(&tm, 2, 6);
        assert!(pt.pipeline.is_valid(2, 6), "{}", net.name);
        assert!(pt.allocation.is_partition(tm.num_layers()));
        assert!(pt.pipeline.cores_used(CoreType::Big) <= 2);
    }
}

#[test]
fn real_pipeline_executor_matches_des_on_synthetic_stages() {
    // Drive the REAL thread pipeline with sleep-stages whose durations come
    // from a DSE point, and compare wall-clock throughput against the DES
    // prediction (coarse: scheduling jitter on a loaded host).
    use pipeit::coordinator::{run_pipeline, StageSpec};
    use std::time::Duration;

    let times = [0.004, 0.006, 0.003];
    let images = 120;
    let stages: Vec<StageSpec<usize>> = times
        .iter()
        .map(|&t| {
            StageSpec::new(
                &format!("sleep{}us", (t * 1e6) as u64),
                Box::new(move || {
                    Box::new(move |x: usize| {
                        std::thread::sleep(Duration::from_secs_f64(t));
                        x
                    })
                }),
            )
        })
        .collect();
    let (_, report) = run_pipeline(stages, 2, 0..images);
    let sim = pipeline_sim::simulate(&times, images, 2);
    let rel = (report.throughput() - sim.throughput).abs() / sim.throughput;
    assert!(
        rel < 0.30,
        "real {} vs DES {} (rel {rel:.2})",
        report.throughput(),
        sim.throughput
    );
}
