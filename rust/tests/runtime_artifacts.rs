//! Integration over the REAL artifacts: manifest -> PJRT runtime ->
//! pipeline, verifying the L1/L2/L3 contract end to end.
//!
//! Requires `make artifacts`; tests are skipped (with a notice) when the
//! artifacts are absent so `cargo test` works in a fresh checkout.

use std::path::Path;

use pipeit::coordinator::{serve_layerwise_serial, serve_pipelined, serve_serial};
use pipeit::dse::Allocation;
use pipeit::runtime::{Manifest, StageRunnerSpec, Tensor};

fn micro() -> Option<Manifest> {
    let dir = Path::new("artifacts/pipenet_micro");
    if !dir.join("manifest.json").is_file() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest loads"))
}

#[test]
fn manifest_contract() {
    let Some(m) = micro() else { return };
    assert_eq!(m.name, "pipenet_micro");
    assert_eq!(m.num_layers(), 4);
    assert_eq!(m.input_shape, vec![16, 16, 3]);
    assert_eq!(m.output_shape, vec![10]);
    assert_eq!(m.batch_sizes, vec![1, 4]);
    // GEMM dims follow Eq. 4: conv1 is 16x16 SAME 3x3x3 -> N=256,K=27.
    assert_eq!(m.layers[0].gemm.n, 256);
    assert_eq!(m.layers[0].gemm.k, 27);
}

#[test]
fn layer_chain_matches_full_module() {
    // Running the per-layer modules in sequence must equal the whole-net
    // module. Build the chain from SINGLE-layer runners so the segment
    // fast path cannot kick in (we want the per-layer modules exercised).
    let Some(m) = micro() else { return };
    let full = StageRunnerSpec::full_network(&m, &[1]).unwrap().build().unwrap();
    let singles: Vec<_> = (0..m.num_layers())
        .map(|i| StageRunnerSpec::from_manifest(&m, i, i + 1, &[1]).unwrap().build().unwrap())
        .collect();
    let mut rng = pipeit::util::rng::Rng::new(3);
    for _ in 0..3 {
        let img = Tensor::new(vec![16, 16, 3], rng.f32_vec(16 * 16 * 3, 0.0, 1.0));
        let a = &full.run_batch(std::slice::from_ref(&img)).unwrap()[0];
        let mut x = img;
        for s in &singles {
            x = s.run_batch(std::slice::from_ref(&x)).unwrap().pop().unwrap();
        }
        assert_eq!(a.shape, vec![10]);
        for (p, q) in a.data.iter().zip(&x.data) {
            assert!((p - q).abs() < 1e-4, "layerwise vs full mismatch: {p} vs {q}");
        }
    }
}

#[test]
fn segment_module_matches_per_layer_chain() {
    // The fused [1,3) segment must equal layers 1 and 2 run separately.
    let Some(m) = micro() else { return };
    if m.segments.is_empty() {
        eprintln!("skipping: artifacts predate segment export");
        return;
    }
    let seg = StageRunnerSpec::from_manifest(&m, 1, 3, &[1]).unwrap();
    // Must have picked the single fused module.
    assert_eq!(seg.batches[0].1.len(), 1, "segment fast path not used");
    let seg = seg.build().unwrap();
    let l1 = StageRunnerSpec::from_manifest(&m, 1, 2, &[1]).unwrap().build().unwrap();
    let l2 = StageRunnerSpec::from_manifest(&m, 2, 3, &[1]).unwrap().build().unwrap();
    let mut rng = pipeit::util::rng::Rng::new(11);
    let img = Tensor::new(
        m.layers[1].input_shape.clone(),
        rng.f32_vec(m.layers[1].input_shape.iter().product(), 0.0, 1.0),
    );
    let a = seg.run_batch(std::slice::from_ref(&img)).unwrap().pop().unwrap();
    let mid = l1.run_batch(std::slice::from_ref(&img)).unwrap().pop().unwrap();
    let b = l2.run_batch(std::slice::from_ref(&mid)).unwrap().pop().unwrap();
    assert_eq!(a.shape, b.shape);
    for (p, q) in a.data.iter().zip(&b.data) {
        assert!((p - q).abs() < 1e-4, "segment vs chain mismatch");
    }
}

#[test]
fn batch4_equals_four_batch1() {
    let Some(m) = micro() else { return };
    let runner = StageRunnerSpec::from_manifest(&m, 0, m.num_layers(), &[1, 4])
        .unwrap()
        .build()
        .unwrap();
    let mut rng = pipeit::util::rng::Rng::new(9);
    let imgs: Vec<Tensor> = (0..4)
        .map(|_| Tensor::new(vec![16, 16, 3], rng.f32_vec(16 * 16 * 3, 0.0, 1.0)))
        .collect();
    let batched = runner.run_batch(&imgs).unwrap();
    for (i, img) in imgs.iter().enumerate() {
        let single = &runner.run_batch(std::slice::from_ref(img)).unwrap()[0];
        for (x, y) in batched[i].data.iter().zip(&single.data) {
            assert!((x - y).abs() < 1e-4, "batch-4 diverges from batch-1");
        }
    }
}

#[test]
fn pipelined_equals_serial_classifications() {
    let Some(m) = micro() else { return };
    let alloc = Allocation { ranges: vec![(0, 2), (2, 4)] };
    let (piped, _) = serve_pipelined(&m, &alloc, 12, 1, 2, 42).unwrap();
    let (serial, _) = serve_serial(&m, 12, 1, 42).unwrap();
    let flat = |jobs: &[pipeit::coordinator::Job]| -> Vec<Vec<f32>> {
        let mut v: Vec<(usize, Vec<f32>)> = jobs
            .iter()
            .flat_map(|j| {
                j.tensors
                    .iter()
                    .enumerate()
                    .map(move |(k, t)| (j.seq + k, t.data.clone()))
            })
            .collect();
        v.sort_by_key(|(s, _)| *s);
        v.into_iter().map(|(_, d)| d).collect()
    };
    let (a, b) = (flat(&piped), flat(&serial));
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        for (p, q) in x.iter().zip(y) {
            assert!((p - q).abs() < 1e-4);
        }
    }
}

#[test]
fn layerwise_serial_runs() {
    let Some(m) = micro() else { return };
    let (jobs, report) = serve_layerwise_serial(&m, 8, 5).unwrap();
    assert_eq!(report.images, 8);
    assert!(report.throughput() > 0.0);
    let n: usize = jobs.iter().map(|j| j.tensors.len()).sum();
    assert_eq!(n, 8);
    assert!(jobs.iter().all(|j| j.tensors.iter().all(|t| t.shape == vec![10])));
}

#[test]
fn bad_layer_range_rejected() {
    let Some(m) = micro() else { return };
    assert!(StageRunnerSpec::from_manifest(&m, 2, 2, &[1]).is_err());
    assert!(StageRunnerSpec::from_manifest(&m, 0, 99, &[1]).is_err());
    assert!(StageRunnerSpec::from_manifest(&m, 0, 1, &[3]).is_err()); // batch 3 not exported
}

#[test]
fn wrong_input_shape_rejected() {
    let Some(m) = micro() else { return };
    let runner =
        StageRunnerSpec::from_manifest(&m, 0, 1, &[1]).unwrap().build().unwrap();
    let bad = Tensor::zeros(&[8, 8, 3]);
    assert!(runner.run_batch(std::slice::from_ref(&bad)).is_err());
}
