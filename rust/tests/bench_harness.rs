//! Acceptance suite for the benchmark harness (ISSUE 5): the quick suite
//! is deterministic (two same-seed runs compare as all-unchanged), covers
//! >= 8 scenarios across >= 4 serving modes, the artifact round-trips, and
//! `--compare` flags an artificially injected 10% slowdown as a regression
//! while passing the no-change case — including the CLI exit codes.

use std::path::Path;
use std::process::Command;

use pipeit::harness::{
    compare, run_suite, BenchReport, RunnerOptions, SampleStats, Suite, Verdict,
    DEFAULT_MIN_REL_DELTA,
};

fn quick_opts() -> RunnerOptions {
    RunnerOptions { reps: 2, warmup: 0, seed: 7, ..Default::default() }
}

/// Scale one scenario's metric by `factor`, recomputing its stats from the
/// scaled samples — the "artificially injected slowdown" of the acceptance
/// criterion.
fn inject(report: &BenchReport, key: &str, factor: f64) -> BenchReport {
    let mut out = report.clone();
    let entry = out
        .scenarios
        .iter_mut()
        .find(|s| s.key() == key)
        .unwrap_or_else(|| panic!("scenario {key} not in the report"));
    for x in &mut entry.samples {
        *x *= factor;
    }
    entry.stats = SampleStats::from_samples(&entry.samples, 3.5, 0.95, 200, 7);
    out
}

#[test]
fn quick_suite_is_deterministic_and_covers_the_floor() {
    let a = run_suite(Suite::Quick, &quick_opts()).expect("first run");
    let b = run_suite(Suite::Quick, &quick_opts()).expect("second run");

    // Acceptance floor: >= 8 scenarios across >= 4 serving modes.
    assert!(a.scenarios.len() >= 8, "only {} scenarios", a.scenarios.len());
    assert!(a.modes().len() >= 4, "only modes {:?}", a.modes());
    for s in &a.scenarios {
        assert!(s.stats.median > 0.0, "{}: zero metric", s.key());
        assert!(
            s.stats.ci_lo <= s.stats.median && s.stats.median <= s.stats.ci_hi,
            "{}: CI does not bracket the median",
            s.key()
        );
    }

    // Determinism: bit-identical samples and stats, all-unchanged compare.
    for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
        assert_eq!(x.samples, y.samples, "{}: samples differ across runs", x.key());
        assert_eq!(x.stats, y.stats, "{}: stats differ across runs", x.key());
    }
    let cmp = compare(&a, &b, DEFAULT_MIN_REL_DELTA);
    assert!(!cmp.has_regressions());
    assert_eq!(cmp.count(Verdict::Unchanged), a.scenarios.len());
    assert_eq!(cmp.count(Verdict::Improved), 0);
}

#[test]
fn injected_slowdown_is_flagged_and_isolated() {
    let base = run_suite(Suite::Quick, &quick_opts()).expect("bench run");
    let key = base.scenarios[0].key();
    let slowed = inject(&base, &key, 0.9);
    let cmp = compare(&base, &slowed, DEFAULT_MIN_REL_DELTA);
    assert!(cmp.has_regressions(), "10% slowdown must gate");
    assert_eq!(cmp.count(Verdict::Regressed), 1, "only the injected scenario");
    let diff = cmp.diffs.iter().find(|d| d.verdict == Verdict::Regressed).unwrap();
    assert_eq!(format!("{}/{}", diff.backend, diff.name), key);
    assert!(
        (diff.rel_delta + 0.1).abs() < 1e-9,
        "delta should be -10%, got {}",
        diff.rel_delta
    );
}

/// ISSUE 9: the recorded DES repetition must land the engine
/// self-profile in the artifact — the quick suite is DES-only, so every
/// scenario's snapshot carries a `prof/{engine}/` catalog entry, which
/// is what makes `pipeit bench history` a trajectory of engine cost too.
#[test]
fn recorded_rep_lands_prof_counters_in_every_scenario() {
    let report = run_suite(Suite::Quick, &quick_opts()).expect("bench run");
    assert_eq!(report.recorded_rep, Some(1), "last of 2 reps is recorded");
    for s in &report.scenarios {
        let m = s
            .metrics
            .as_ref()
            .unwrap_or_else(|| panic!("{}: no recorded snapshot", s.key()));
        assert!(
            m.counters
                .keys()
                .any(|k| k.starts_with("prof/") && k.ends_with("/events")),
            "{}: no prof/*/events counter in the snapshot",
            s.key()
        );
        assert!(
            m.gauges
                .keys()
                .any(|k| k.starts_with("prof/") && k.ends_with("/events_per_s")),
            "{}: no prof/*/events_per_s gauge in the snapshot",
            s.key()
        );
    }
}

#[test]
fn bench_report_roundtrips_through_the_artifact_file() {
    let report = run_suite(Suite::Quick, &quick_opts()).expect("bench run");
    let path = std::env::temp_dir().join("pipeit_bench_roundtrip_test.json");
    report.save(&path).expect("artifact written");
    let loaded = BenchReport::load(&path).expect("artifact reloads");
    assert_eq!(report, loaded, "BENCH artifact must round-trip losslessly");
    std::fs::remove_file(&path).ok();
}

// ---- CLI end-to-end (the acceptance criterion verbatim) -----------------

fn pipeit(args: &[&str]) -> (std::process::ExitStatus, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_pipeit"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("binary runs");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status, text)
}

#[test]
fn cli_bench_twice_same_seed_compares_all_unchanged_and_gates_a_slowdown() {
    let dir = std::env::temp_dir();
    let f1 = dir.join("pipeit_BENCH_cli_a.json");
    let f2 = dir.join("pipeit_BENCH_cli_b.json");
    let f3 = dir.join("pipeit_BENCH_cli_slow.json");
    let (f1s, f2s, f3s) =
        (f1.to_str().unwrap(), f2.to_str().unwrap(), f3.to_str().unwrap());

    // Two same-seed quick runs (reps trimmed to keep the test fast).
    for out in [f1s, f2s] {
        let (status, text) = pipeit(&[
            "bench", "--suite", "quick", "--seed", "7", "--reps", "2", "--warmup",
            "0", "--out", out,
        ]);
        assert!(status.success(), "{text}");
        assert!(text.contains("bench suite: quick"), "{text}");
        assert!(text.contains("bench saved"), "{text}");
    }

    // Determinism gate: all-unchanged, exit 0.
    let (status, text) = pipeit(&["bench", "--compare", f1s, f2s]);
    assert!(status.success(), "no-change compare must exit 0:\n{text}");
    assert!(text.contains("0 improved, 0 regressed"), "{text}");

    // Inject a 10% slowdown into one scenario and re-compare: REGRESSED,
    // non-zero exit.
    let base = BenchReport::load(Path::new(f1s)).expect("artifact reloads");
    let slowed = inject(&base, &base.scenarios[0].key(), 0.9);
    slowed.save(&f3).expect("tampered artifact written");
    let (status, text) = pipeit(&["bench", "--compare", f1s, f3s]);
    assert!(!status.success(), "regression must exit non-zero:\n{text}");
    assert!(text.contains("REGRESSED"), "{text}");
    assert!(text.contains("1 regressed"), "{text}");

    for f in [&f1, &f2, &f3] {
        std::fs::remove_file(f).ok();
    }
}

/// `bench history` end to end (ISSUE 9): two artifacts in a directory
/// render as a two-column trajectory, `--dat` writes the gnuplot form,
/// run-only knobs are rejected, and an artifact-free directory gets the
/// getting-started error instead of an empty table.
#[test]
fn cli_bench_history_renders_table_and_dat() {
    let dir = std::env::temp_dir()
        .join(format!("pipeit_bench_history_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let report = run_suite(Suite::Quick, &quick_opts()).expect("bench run");
    report.save(&dir.join("BENCH_0.json")).expect("artifact written");
    report.save(&dir.join("BENCH_1.json")).expect("artifact written");

    let dat = dir.join("history.dat");
    let (status, text) = pipeit(&[
        "bench", "history", dir.to_str().unwrap(), "--dat", dat.to_str().unwrap(),
    ]);
    assert!(status.success(), "{text}");
    assert!(text.contains("bench history: 2 artifacts"), "{text}");
    assert!(text.contains("Bench trajectory"), "{text}");
    assert!(text.contains("first->last"), "{text}");
    assert!(text.contains("dat saved"), "{text}");
    let dat_text = std::fs::read_to_string(&dat).expect("dat written");
    assert!(dat_text.starts_with("# label "), "{dat_text}");
    assert_eq!(dat_text.lines().count(), 3, "header + one row per artifact");
    assert!(!dat_text.contains("nan"), "identical artifacts leave no holes");

    // Run-only knobs must not be silently dropped on the history form.
    let (status, text) =
        pipeit(&["bench", "history", dir.to_str().unwrap(), "--reps", "9"]);
    assert!(!status.success());
    assert!(text.contains("--reps"), "{text}");

    let empty = dir.join("empty");
    std::fs::create_dir_all(&empty).expect("temp dir");
    let (status, text) = pipeit(&["bench", "history", empty.to_str().unwrap()]);
    assert!(!status.success());
    assert!(text.contains("no BENCH_*.json"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_bench_rejects_bad_inputs() {
    let (status, text) = pipeit(&["bench", "--suite", "nightly"]);
    assert!(!status.success());
    assert!(text.contains("unknown suite"), "{text}");

    // Seeds ride through the f64-backed JSON artifact: 2^53 and above
    // would round silently, so the CLI rejects them up front.
    let (status, text) = pipeit(&["bench", "--seed", "9007199254740993"]);
    assert!(!status.success());
    assert!(text.contains("2^53"), "{text}");

    // Run-only and compare-only knobs must not be silently dropped.
    let (status, text) = pipeit(&["bench", "--suite", "quick", "--min-delta", "0.05"]);
    assert!(!status.success());
    assert!(text.contains("--min-delta"), "{text}");
    let (status, text) =
        pipeit(&["bench", "--compare", "a.json", "b.json", "--reps", "9"]);
    assert!(!status.success());
    assert!(text.contains("--reps"), "{text}");

    let (status, text) = pipeit(&["bench", "--compare", "/nonexistent/a.json"]);
    assert!(!status.success());
    assert!(text.contains("two artifacts"), "{text}");

    let (status, text) =
        pipeit(&["bench", "--compare", "/nonexistent/a.json", "/nonexistent/b.json"]);
    assert!(!status.success());
    assert!(text.contains("a.json"), "{text}");
}
