//! CLI smoke tests: every subcommand runs and prints what it promises.

use std::process::Command;

fn pipeit(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_pipeit"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("binary runs");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn no_args_prints_usage() {
    let (ok, text) = pipeit(&[]);
    assert!(ok);
    assert!(text.contains("USAGE"));
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, text) = pipeit(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn count_prints_design_space() {
    let (ok, text) = pipeit(&["count"]);
    assert!(ok, "{text}");
    assert!(text.contains("pipelines on 4B+4s: 64"), "{text}");
    assert!(text.contains("mobilenet"));
}

#[test]
fn explore_resnet() {
    let (ok, text) = pipeit(&["explore", "--net", "resnet50"]);
    assert!(ok, "{text}");
    assert!(text.contains("pipeline"));
    assert!(text.contains("imgs/s"));
}

#[test]
fn explore_unknown_net_fails() {
    let (ok, text) = pipeit(&["explore", "--net", "vgg19"]);
    assert!(!ok);
    assert!(text.contains("unknown network"));
}

#[test]
fn simulate_with_pipeline() {
    let (ok, text) = pipeit(&[
        "simulate", "--net", "resnet50", "--pipeline", "B4-s2-s2", "--images", "100",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("sim tp"));
    assert!(text.contains("bottleneck"));
}

#[test]
fn simulate_rejects_over_budget_pipeline() {
    let (ok, text) = pipeit(&["simulate", "--net", "alexnet", "--pipeline", "B4-B1-s4"]);
    assert!(!ok);
    assert!(text.contains("core budget"), "{text}");
}

#[test]
fn predict_prints_matrix() {
    let (ok, text) = pipeit(&["predict", "--net", "alexnet"]);
    assert!(ok, "{text}");
    assert!(text.contains("conv1"));
    assert!(text.contains("fc8"));
}

#[test]
fn platform_flag_is_honoured() {
    let (ok, text) = pipeit(&[
        "count",
        "--platform",
        "configs/asymmetric_2big_6small.json",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("pipelines on 2B+6s"), "{text}");
}

#[test]
fn count_prints_replicated_space() {
    let (ok, text) = pipeit(&["count", "--max-replicas", "2"]);
    assert!(ok, "{text}");
    assert!(text.contains("replicated (R<=2)"), "{text}");
    assert!(text.contains("core partitions"), "{text}");
}

#[test]
fn explore_replicated_reports_fleet() {
    let (ok, text) = pipeit(&["explore", "--net", "alexnet", "--replicated"]);
    assert!(ok, "{text}");
    assert!(text.contains("replicated"), "{text}");
    assert!(text.contains("aggregate"), "{text}");
    assert!(text.contains("vs best single pipeline"), "{text}");
}

#[test]
fn serve_simulated_fleet_two_replicas() {
    let (ok, text) = pipeit(&[
        "serve", "--net", "alexnet", "--replicas", "2", "--images", "16",
        "--time-scale", "0.02",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("fleet"), "{text}");
    assert!(text.contains("aggregate"), "{text}");
    assert!(text.contains("replica 1"), "{text}");
}

#[test]
fn serve_simulated_single_replica() {
    let (ok, text) = pipeit(&[
        "serve", "--net", "squeezenet", "--images", "10", "--time-scale", "0.02",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("fleet: 1 replicas"), "{text}");
}

#[test]
fn serve_without_target_fails_with_usage() {
    let (ok, text) = pipeit(&["serve"]);
    assert!(!ok);
    assert!(text.contains("--net") || text.contains("--artifacts"), "{text}");
}

#[test]
fn plan_without_out_prints_summary() {
    let (ok, text) = pipeit(&["plan", "--net", "mobilenet", "--strategy", "exhaustive"]);
    assert!(ok, "{text}");
    assert!(text.contains("strategy   : exhaustive"), "{text}");
    assert!(text.contains("throughput"), "{text}");
}

#[test]
fn plan_with_unknown_strategy_fails() {
    let (ok, text) = pipeit(&["plan", "--net", "mobilenet", "--strategy", "magic"]);
    assert!(!ok);
    assert!(text.contains("unknown strategy"), "{text}");
}

#[test]
fn serve_missing_plan_file_fails_cleanly() {
    let (ok, text) = pipeit(&["serve", "--plan", "/nonexistent/plan.json"]);
    assert!(!ok);
    assert!(text.contains("plan.json"), "{text}");
}

#[test]
fn serve_adapt_runs_clean_without_disturbance() {
    // Drift threshold far above scheduler jitter: the adaptive loop must
    // pass everything through with zero swaps.
    let (ok, text) = pipeit(&[
        "serve", "--net", "squeezenet", "--adapt", "--images", "24",
        "--adapt-interval", "8", "--time-scale", "0.02", "--drift-threshold", "9",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("adaptations: 0"), "{text}");
    assert!(text.contains("aggregate"), "{text}");
}

#[test]
fn serve_throttle_without_adapt_is_a_baseline_run() {
    let (ok, text) = pipeit(&[
        "serve", "--net", "squeezenet", "--throttle", "9999:2:big", "--images", "12",
        "--adapt-interval", "6", "--time-scale", "0.02",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("adaptation : disabled"), "{text}");
    assert!(text.contains("throttle   :"), "{text}");
}

#[test]
fn serve_rejects_malformed_throttle_spec() {
    let (ok, text) = pipeit(&[
        "serve", "--net", "squeezenet", "--adapt", "--throttle", "garbage",
    ]);
    assert!(!ok);
    assert!(text.contains("throttle"), "{text}");
}

#[test]
fn serve_adapt_rejects_artifact_serving() {
    let (ok, text) = pipeit(&[
        "serve", "--artifacts", "artifacts/pipenet_tiny", "--adapt",
    ]);
    assert!(!ok);
    assert!(text.contains("--adapt"), "{text}");
}

#[test]
fn serve_metrics_out_writes_the_report_json() {
    let path = std::env::temp_dir().join("pipeit_cli_metrics_test.json");
    let path_s = path.to_str().unwrap();
    let (ok, text) = pipeit(&[
        "serve", "--net", "squeezenet", "--images", "10", "--time-scale", "0.02",
        "--metrics-out", path_s,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("metrics    :"), "{text}");
    let json = std::fs::read_to_string(&path).expect("metrics file written");
    assert!(json.contains("\"throughput\""), "{json}");
    assert!(json.contains("\"replicas\""), "{json}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn simulate_metrics_out_writes_des_report() {
    let path = std::env::temp_dir().join("pipeit_cli_metrics_sim_test.json");
    let path_s = path.to_str().unwrap();
    let (ok, text) = pipeit(&[
        "simulate", "--net", "alexnet", "--pipeline", "B4-s4", "--images", "50",
        "--metrics-out", path_s,
    ]);
    assert!(ok, "{text}");
    let json = std::fs::read_to_string(&path).expect("metrics file written");
    assert!(json.contains("\"des\""), "{json}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn serve_serial_on_artifacts() {
    // Only when artifacts exist (built by `make artifacts`).
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/pipenet_micro/manifest.json");
    if !dir.is_file() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (ok, text) = pipeit(&[
        "serve", "--artifacts", "artifacts/pipenet_micro", "--images", "6", "--serial",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("throughput="), "{text}");
}
