//! CLI smoke tests: every subcommand runs and prints what it promises.

use std::process::Command;

fn pipeit(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_pipeit"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("binary runs");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn no_args_prints_usage() {
    let (ok, text) = pipeit(&[]);
    assert!(ok);
    assert!(text.contains("USAGE"));
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, text) = pipeit(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn count_prints_design_space() {
    let (ok, text) = pipeit(&["count"]);
    assert!(ok, "{text}");
    assert!(text.contains("pipelines on 4B+4s: 64"), "{text}");
    assert!(text.contains("mobilenet"));
}

#[test]
fn explore_resnet() {
    let (ok, text) = pipeit(&["explore", "--net", "resnet50"]);
    assert!(ok, "{text}");
    assert!(text.contains("pipeline"));
    assert!(text.contains("imgs/s"));
}

#[test]
fn explore_unknown_net_fails() {
    let (ok, text) = pipeit(&["explore", "--net", "vgg19"]);
    assert!(!ok);
    assert!(text.contains("unknown network"));
}

#[test]
fn simulate_with_pipeline() {
    let (ok, text) = pipeit(&[
        "simulate", "--net", "resnet50", "--pipeline", "B4-s2-s2", "--images", "100",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("sim tp"));
    assert!(text.contains("bottleneck"));
}

#[test]
fn simulate_rejects_over_budget_pipeline() {
    let (ok, text) = pipeit(&["simulate", "--net", "alexnet", "--pipeline", "B4-B1-s4"]);
    assert!(!ok);
    assert!(text.contains("core budget"), "{text}");
}

#[test]
fn predict_prints_matrix() {
    let (ok, text) = pipeit(&["predict", "--net", "alexnet"]);
    assert!(ok, "{text}");
    assert!(text.contains("conv1"));
    assert!(text.contains("fc8"));
}

#[test]
fn platform_flag_is_honoured() {
    let (ok, text) = pipeit(&[
        "count",
        "--platform",
        "configs/asymmetric_2big_6small.json",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("pipelines on 2B+6s"), "{text}");
}

#[test]
fn count_prints_replicated_space() {
    let (ok, text) = pipeit(&["count", "--max-replicas", "2"]);
    assert!(ok, "{text}");
    assert!(text.contains("replicated (R<=2)"), "{text}");
    assert!(text.contains("core partitions"), "{text}");
}

#[test]
fn explore_replicated_reports_fleet() {
    let (ok, text) = pipeit(&["explore", "--net", "alexnet", "--replicated"]);
    assert!(ok, "{text}");
    assert!(text.contains("replicated"), "{text}");
    assert!(text.contains("aggregate"), "{text}");
    assert!(text.contains("vs best single pipeline"), "{text}");
}

#[test]
fn serve_simulated_fleet_two_replicas() {
    let (ok, text) = pipeit(&[
        "serve", "--net", "alexnet", "--replicas", "2", "--images", "16",
        "--time-scale", "0.02",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("fleet"), "{text}");
    assert!(text.contains("aggregate"), "{text}");
    assert!(text.contains("replica 1"), "{text}");
}

#[test]
fn serve_simulated_single_replica() {
    let (ok, text) = pipeit(&[
        "serve", "--net", "squeezenet", "--images", "10", "--time-scale", "0.02",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("fleet: 1 replicas"), "{text}");
}

#[test]
fn serve_without_target_fails_with_usage() {
    let (ok, text) = pipeit(&["serve"]);
    assert!(!ok);
    assert!(text.contains("--net") || text.contains("--artifacts"), "{text}");
}

#[test]
fn plan_without_out_prints_summary() {
    let (ok, text) = pipeit(&["plan", "--net", "mobilenet", "--strategy", "exhaustive"]);
    assert!(ok, "{text}");
    assert!(text.contains("strategy   : exhaustive"), "{text}");
    assert!(text.contains("throughput"), "{text}");
}

#[test]
fn plan_with_unknown_strategy_fails() {
    let (ok, text) = pipeit(&["plan", "--net", "mobilenet", "--strategy", "magic"]);
    assert!(!ok);
    assert!(text.contains("unknown strategy"), "{text}");
}

#[test]
fn serve_missing_plan_file_fails_cleanly() {
    let (ok, text) = pipeit(&["serve", "--plan", "/nonexistent/plan.json"]);
    assert!(!ok);
    assert!(text.contains("plan.json"), "{text}");
}

#[test]
fn serve_adapt_runs_clean_without_disturbance() {
    // Drift threshold far above scheduler jitter: the adaptive loop must
    // pass everything through with zero swaps.
    let (ok, text) = pipeit(&[
        "serve", "--net", "squeezenet", "--adapt", "--images", "24",
        "--adapt-interval", "8", "--time-scale", "0.02", "--drift-threshold", "9",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("adaptations: 0"), "{text}");
    assert!(text.contains("aggregate"), "{text}");
}

#[test]
fn serve_throttle_without_adapt_is_a_baseline_run() {
    let (ok, text) = pipeit(&[
        "serve", "--net", "squeezenet", "--throttle", "9999:2:big", "--images", "12",
        "--adapt-interval", "6", "--time-scale", "0.02",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("adaptation : disabled"), "{text}");
    assert!(text.contains("throttle   :"), "{text}");
}

#[test]
fn serve_rejects_malformed_throttle_spec() {
    let (ok, text) = pipeit(&[
        "serve", "--net", "squeezenet", "--adapt", "--throttle", "garbage",
    ]);
    assert!(!ok);
    assert!(text.contains("throttle"), "{text}");
}

#[test]
fn serve_adapt_rejects_artifact_serving() {
    let (ok, text) = pipeit(&[
        "serve", "--artifacts", "artifacts/pipenet_tiny", "--adapt",
    ]);
    assert!(!ok);
    assert!(text.contains("--adapt"), "{text}");
}

#[test]
fn serve_metrics_out_writes_the_report_json() {
    let path = std::env::temp_dir().join("pipeit_cli_metrics_test.json");
    let path_s = path.to_str().unwrap();
    let (ok, text) = pipeit(&[
        "serve", "--net", "squeezenet", "--images", "10", "--time-scale", "0.02",
        "--metrics-out", path_s,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("metrics    :"), "{text}");
    let json = std::fs::read_to_string(&path).expect("metrics file written");
    assert!(json.contains("\"throughput\""), "{json}");
    assert!(json.contains("\"replicas\""), "{json}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn simulate_metrics_out_writes_des_report() {
    let path = std::env::temp_dir().join("pipeit_cli_metrics_sim_test.json");
    let path_s = path.to_str().unwrap();
    let (ok, text) = pipeit(&[
        "simulate", "--net", "alexnet", "--pipeline", "B4-s4", "--images", "50",
        "--metrics-out", path_s,
    ]);
    assert!(ok, "{text}");
    let json = std::fs::read_to_string(&path).expect("metrics file written");
    assert!(json.contains("\"des\""), "{json}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn plan_multi_saves_and_simulate_multi_loads() {
    let path = std::env::temp_dir().join("pipeit_cli_multiplan_test.json");
    let path_s = path.to_str().unwrap();
    let (ok, text) = pipeit(&[
        "plan-multi",
        "--tenant", "net=alexnet,rate=4",
        "--tenant", "net=squeezenet,rate=8,p99=5s,weight=2",
        "--out", path_s,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("co-serving : 2 tenants"), "{text}");
    assert!(text.contains("tenant alexnet"), "{text}");
    assert!(text.contains("p99<=5000ms"), "{text}");
    assert!(text.contains("plan saved"), "{text}");

    let (ok, text) = pipeit(&["simulate-multi", "--plan", path_s, "--images", "200"]);
    assert!(ok, "{text}");
    assert!(text.contains("(DES)"), "{text}");
    assert!(text.contains("SLAs"), "{text}");
    assert!(text.contains("board util"), "{text}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn serve_multi_runs_wall_clock_fleets() {
    let (ok, text) = pipeit(&[
        "serve-multi",
        "--tenant", "net=alexnet,rate=6",
        "--tenant", "net=squeezenet,rate=12",
        "--images", "6", "--time-scale", "0.02",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("wall-clock"), "{text}");
    assert!(text.contains("tenant squeezenet"), "{text}");
    assert!(text.contains("served"), "{text}");
}

#[test]
fn simulate_multi_metrics_out_writes_json() {
    let path = std::env::temp_dir().join("pipeit_cli_multi_metrics_test.json");
    let path_s = path.to_str().unwrap();
    let (ok, text) = pipeit(&[
        "simulate-multi",
        "--tenant", "net=alexnet,rate=5",
        "--tenant", "net=squeezenet,rate=10,p99=5s",
        "--images", "150", "--metrics-out", path_s,
    ]);
    assert!(ok, "{text}");
    let json = std::fs::read_to_string(&path).expect("metrics file written");
    assert!(json.contains("\"weighted_throughput\""), "{json}");
    assert!(json.contains("\"sla_ok\""), "{json}");
    assert!(json.contains("\"shed\""), "{json}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn plan_multi_rejects_malformed_tenant() {
    let (ok, text) = pipeit(&["plan-multi", "--tenant", "net=alexnet"]);
    assert!(!ok);
    assert!(text.contains("rate"), "{text}");
    let (ok, text) = pipeit(&["plan-multi", "--tenant", "net=vgg19,rate=5"]);
    assert!(!ok);
    assert!(text.contains("unknown network"), "{text}");
    let (ok, text) = pipeit(&["serve-multi"]);
    assert!(!ok);
    assert!(text.contains("--tenant"), "{text}");
}

#[test]
fn serve_multi_plan_rejects_compile_options() {
    let path = std::env::temp_dir().join("pipeit_cli_multi_reject_test.json");
    let path_s = path.to_str().unwrap();
    let (ok, text) = pipeit(&[
        "plan-multi", "--tenant", "net=squeezenet,rate=8", "--out", path_s,
    ]);
    assert!(ok, "{text}");
    let (ok, text) = pipeit(&[
        "simulate-multi", "--plan", path_s, "--tenant", "net=alexnet,rate=4",
    ]);
    assert!(!ok);
    assert!(text.contains("plan-compile option"), "{text}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn simulate_open_loop_arrival_is_reproducible() {
    let run = || {
        pipeit(&[
            "simulate", "--net", "alexnet", "--pipeline", "B4-s4",
            "--arrival", "poisson:4:123", "--images", "80", "--p99", "10s",
        ])
    };
    let (ok, text) = run();
    assert!(ok, "{text}");
    assert!(text.contains("arrival    : poisson:4:123"), "{text}");
    assert!(text.contains("co-serving : 1 tenants"), "{text}");
    assert!(text.contains("SLA p99<=10000ms"), "{text}");
    let (ok2, text2) = run();
    assert!(ok2);
    assert_eq!(text, text2, "seeded open-loop runs must be byte-identical");
}

#[test]
fn serve_open_loop_arrival_wall_clock() {
    let (ok, text) = pipeit(&[
        "serve", "--net", "squeezenet", "--arrival", "uniform:8",
        "--images", "6", "--time-scale", "0.02",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("arrival    : uniform:8"), "{text}");
    assert!(text.contains("wall-clock"), "{text}");
}

#[test]
fn arrival_rejects_bad_spec_and_adapt_combination() {
    let (ok, text) = pipeit(&[
        "simulate", "--net", "alexnet", "--pipeline", "B4-s4", "--arrival", "burst:9",
    ]);
    assert!(!ok);
    assert!(text.contains("bad arrival spec"), "{text}");
    let (ok, text) = pipeit(&[
        "serve", "--net", "alexnet", "--arrival", "poisson:5", "--adapt",
    ]);
    assert!(!ok);
    assert!(text.contains("--arrival"), "{text}");
}

#[test]
fn serve_serial_on_artifacts() {
    // Only when artifacts exist (built by `make artifacts`).
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/pipenet_micro/manifest.json");
    if !dir.is_file() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (ok, text) = pipeit(&[
        "serve", "--artifacts", "artifacts/pipenet_micro", "--images", "6", "--serial",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("throughput="), "{text}");
}
