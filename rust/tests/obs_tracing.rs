//! Observability acceptance suite (ISSUE 8): span chains must conserve
//! items on both execution twins, same-seed DES traces must be
//! byte-identical, a recorder — enabled or disabled — must never change a
//! scenario's metric, and the registry's occupancy/service histograms
//! must account for the busy time the report's utilization column claims.
//!
//! These tests exercise the recorded entry points the way `--trace-out`
//! does: every registry scenario through [`Scenario::run_recorded`], plus
//! a hand-built two-board cluster plan for the busy-time accounting check.

use pipeit::cluster::{
    BoardSpec, ClusterPlan, ClusterServeOptions, ClusterSpec, DispatchPolicy,
};
use pipeit::config::Config;
use pipeit::harness::{registry, Backend};
use pipeit::obs::{
    attribute, audit_chains, chrome_trace, parse_trace, trace_to_jsonl,
    PredictedTimes, Recorder,
};
use pipeit::tenancy::TenantSpec;

/// Chain conservation on the DES twin, for every registry scenario:
/// each admitted item leaves exactly one complete admit → stages →
/// depart chain, each shed item exactly one lone shed span, and the
/// span-derived tallies agree with the metrics registry's counters.
#[test]
fn des_span_chains_conserve_every_item_in_every_registry_scenario() {
    for s in registry() {
        let rec = Recorder::on();
        let (metric, snap) = s.run_recorded(Backend::Des, 42, &rec).unwrap();
        assert!(metric > 0.0, "{}: degenerate metric", s.name);
        let snap = snap.unwrap_or_else(|| panic!("{}: no snapshot", s.name));

        let spans = rec.spans_sorted();
        assert!(!spans.is_empty(), "{}: recorded no spans", s.name);
        let audit = audit_chains(&spans)
            .unwrap_or_else(|e| panic!("{}: {e:#}", s.name));

        assert_eq!(
            audit.complete as u64,
            snap.counter("departed"),
            "{}: complete chains vs departed counter",
            s.name
        );
        assert_eq!(
            snap.counter("admitted"),
            snap.counter("departed"),
            "{}: closed-loop run must drain every admitted item",
            s.name
        );
        assert_eq!(
            audit.shed as u64,
            snap.counter("shed"),
            "{}: lone shed spans vs shed counter",
            s.name
        );

        // Every stage span is one observation in a stage_service
        // histogram, and every departure is one latency observation.
        let service_obs: u64 = snap
            .hists
            .iter()
            .filter(|(k, _)| k.starts_with("stage_service/"))
            .map(|(_, h)| h.count())
            .sum();
        assert_eq!(
            service_obs, audit.stage_spans as u64,
            "{}: stage_service observations vs stage spans",
            s.name
        );
        let latency = snap
            .hist("latency")
            .unwrap_or_else(|| panic!("{}: no latency histogram", s.name));
        assert_eq!(
            latency.count(),
            snap.counter("departed"),
            "{}: latency observations vs departures",
            s.name
        );
    }
}

/// Attribution acceptance (ISSUE 9): on every registry DES scenario the
/// latency decomposition must conserve — each item's front-door wait +
/// queue wait + stage service reproduces its end-to-end latency within
/// 1e-9 (the sum telescopes; anything bigger is a decomposition bug, not
/// float noise) — the chain tallies must match the registry counters,
/// and the engine that ran must have self-profiled into the
/// `prof/{engine}/` namespace (full catalog: counters, high-water
/// gauges, and the events-per-wall-second headline).
#[test]
fn attribution_conserves_and_engines_self_profile_in_every_des_scenario() {
    for s in registry() {
        let rec = Recorder::on();
        let (_, snap) = s.run_recorded(Backend::Des, 13, &rec).unwrap();
        let snap = snap.unwrap_or_else(|| panic!("{}: no snapshot", s.name));

        let a = attribute(&rec.spans_sorted(), &PredictedTimes::new())
            .unwrap_or_else(|e| panic!("{}: {e:#}", s.name));
        assert_eq!(a.items, snap.counter("departed"), "{}: attributed items", s.name);
        assert_eq!(a.shed, snap.counter("shed"), "{}: attributed sheds", s.name);
        assert!(
            a.max_abs_err_s <= 1e-9,
            "{}: decomposition leaks {}s",
            s.name,
            a.max_abs_err_s
        );
        let recomposed = a.front_wait_s + a.queue_wait_s + a.service_s;
        assert!(
            (recomposed - a.latency_s).abs() <= 1e-9,
            "{}: mean decomposition {recomposed} vs latency {}",
            s.name,
            a.latency_s
        );
        assert!(!a.stages.is_empty(), "{}: no per-stage rows", s.name);

        let engines: Vec<String> = snap
            .counters
            .keys()
            .filter_map(|k| k.strip_prefix("prof/")?.strip_suffix("/events"))
            .map(str::to_string)
            .collect();
        assert!(!engines.is_empty(), "{}: engine did not self-profile", s.name);
        for e in &engines {
            for c in ["heap_pushes", "heap_pops", "scan_iters", "wall_ns"] {
                assert!(
                    snap.counters.contains_key(&format!("prof/{e}/{c}")),
                    "{}: missing prof/{e}/{c}",
                    s.name
                );
            }
            for g in ["heap_peak", "ring_peak"] {
                assert!(
                    snap.gauge(&format!("prof/{e}/{g}")).is_some(),
                    "{}: missing prof/{e}/{g}",
                    s.name
                );
            }
            let eps = snap
                .gauge(&format!("prof/{e}/events_per_s"))
                .unwrap_or_else(|| panic!("{}: missing prof/{e}/events_per_s", s.name));
            assert!(eps > 0.0, "{}: prof/{e}/events_per_s = {eps}", s.name);
        }
    }
}

/// Same seed, same scenario → byte-identical JSONL trace dumps. This is
/// the determinism contract `--trace-out` advertises (DESIGN.md §13).
#[test]
fn same_seed_des_traces_are_byte_identical() {
    for s in registry() {
        let dump = |seed: u64| {
            let rec = Recorder::on();
            s.run_recorded(Backend::Des, seed, &rec).unwrap();
            trace_to_jsonl(&rec, "sim")
        };
        let a = dump(7);
        let b = dump(7);
        assert!(!a.is_empty());
        assert_eq!(a, b, "{}: same-seed traces differ", s.name);
    }
}

/// Recording must be free of observer effects on the DES twin: the
/// metric is bit-identical whether the recorder is off, on, or absent,
/// and a disabled recorder yields no snapshot (so reports look exactly
/// as they did before the subsystem existed).
#[test]
fn recording_leaves_the_des_metric_bit_identical() {
    for s in registry() {
        let plain = s.run(Backend::Des, 11).unwrap();
        let (off, snap_off) =
            s.run_recorded(Backend::Des, 11, &Recorder::off()).unwrap();
        let (on, snap_on) =
            s.run_recorded(Backend::Des, 11, &Recorder::on()).unwrap();
        assert_eq!(plain.to_bits(), off.to_bits(), "{}: off-recorder drift", s.name);
        assert_eq!(plain.to_bits(), on.to_bits(), "{}: on-recorder drift", s.name);
        assert!(snap_off.is_none(), "{}: disabled recorder made a snapshot", s.name);
        assert!(snap_on.is_some(), "{}: enabled recorder lost its snapshot", s.name);
    }
}

/// Chain conservation on the wall-clock twin. Wall timestamps are not
/// reproducible, so there is no byte-identity here — only conservation:
/// every admitted item still leaves one complete chain whose stage spans
/// run in pipeline order on one replica. The adaptive scenario is
/// metrics-only on the wall path (its controller swaps fleets mid-run),
/// so this covers one single-plan and one cluster scenario.
#[test]
fn wall_twin_chains_conserve_admitted_items() {
    for name in ["pipelined/alexnet", "cluster/alexnet-2x4+4"] {
        let s = registry()
            .into_iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("scenario {name} left the registry"));
        let rec = Recorder::on();
        let (_, snap) = s.run_recorded(Backend::Wall, 3, &rec).unwrap();
        let snap = snap.unwrap();
        let audit = audit_chains(&rec.spans_sorted())
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert!(audit.complete > 0, "{name}: no complete chains");
        assert_eq!(audit.complete as u64, snap.counter("departed"), "{name}");
        assert_eq!(audit.shed as u64, snap.counter("shed"), "{name}");
    }
}

/// The JSONL dump round-trips through the parser, and the Chrome-trace
/// conversion has the shape Perfetto expects: a `traceEvents` array with
/// complete `X` duration events on stage tracks, instant events on the
/// front-door track, and metadata naming every track.
#[test]
fn trace_jsonl_round_trips_and_converts_to_chrome_shape() {
    let s = registry()
        .into_iter()
        .find(|s| s.name == "cluster/alexnet-2x4+4")
        .unwrap();
    let rec = Recorder::on();
    s.run_recorded(Backend::Des, 5, &rec).unwrap();

    let jsonl = trace_to_jsonl(&rec, "sim");
    let (clock, spans) = parse_trace(&jsonl).unwrap();
    assert_eq!(clock, "sim");
    assert_eq!(spans, rec.spans_sorted(), "JSONL round-trip lost spans");

    let chrome = chrome_trace(&spans);
    let events = chrome.req("traceEvents").unwrap().as_arr().unwrap();
    let ph = |tag: &str| {
        events
            .iter()
            .filter(|e| e.req("ph").unwrap().as_str() == Some(tag))
            .count()
    };
    assert!(ph("X") > 0, "no duration events");
    assert!(ph("i") > 0, "no instant events");
    assert!(ph("M") >= 2, "missing track metadata");
    assert_eq!(ph("X") + ph("i") + ph("M"), events.len());
    assert_eq!(
        chrome.req("displayTimeUnit").unwrap().as_str(),
        Some("ms")
    );
}

/// The acceptance bar from ISSUE 8: on a two-board cluster DES run, the
/// per-stage service histograms must explain ≥ 95% of the busy time the
/// report's utilization column implies. Both sides are exact in the DES
/// (occupancy · makespan = service_time · dispatch_count = histogram
/// sum), so the 95% floor has slack only for float accumulation; the
/// per-board occupancy maximum must equal the utilization column itself.
#[test]
fn cluster_occupancy_histograms_explain_report_utilization() {
    let spec = ClusterSpec {
        boards: vec![BoardSpec::new(4, 4), BoardSpec::new(4, 4)],
        workloads: vec![TenantSpec::new("alexnet", 1.0)],
        max_replicas: 2,
    };
    let mut cp = ClusterPlan::compile(&spec, &Config::default()).unwrap();
    cp.workloads[0].rate_hz = 3.0 * cp.capacity();

    let opts = ClusterServeOptions {
        images: 400,
        policy: DispatchPolicy::LeastOutstanding,
        ..Default::default()
    };
    let rec = Recorder::on();
    let report = cp.simulate_recorded(&opts, &rec).unwrap();
    let snap = report.metrics.as_ref().unwrap();
    assert!(report.shed > 0, "saturated run should shed");

    let spans = rec.spans_sorted();
    audit_chains(&spans).unwrap();
    for (b, board) in report.boards.iter().enumerate() {
        // The board's horizon is its last departure — exactly the
        // makespan the simulator normalized occupancy by.
        let makespan = spans
            .iter()
            .filter(|s| s.group == b as u32)
            .map(|s| s.t1)
            .fold(0.0, f64::max);
        assert!(makespan > 0.0);

        let occ = snap.gauges_with_prefix(&format!("occupancy/g{b}"));
        assert!(!occ.is_empty(), "board {b}: no occupancy gauges");
        let max_occ = occ.iter().map(|&(_, v)| v).fold(0.0, f64::max);
        assert!(
            (max_occ - board.utilization).abs() < 1e-9,
            "board {b}: max occupancy {max_occ} vs utilization {}",
            board.utilization
        );

        let implied: f64 = occ.iter().map(|&(_, v)| v * makespan).sum();
        let measured: f64 = snap
            .hists
            .iter()
            .filter(|(k, _)| k.starts_with(&format!("stage_service/g{b}")))
            .map(|(_, h)| h.sum())
            .sum();
        assert!(
            measured >= 0.95 * implied && measured <= implied * (1.0 + 1e-9),
            "board {b}: histograms explain {measured:.4}s of {implied:.4}s busy"
        );
    }
}
