//! Cluster-scale serving acceptance suite (ISSUE 7): the heterogeneous
//! fleet behind one front door must be deterministic, lossless across the
//! plan artifact, capacity-honest under saturation, and strictly better
//! with load-aware dispatch than with blind round-robin.
//!
//! These tests exercise the public `pipeit::cluster` surface the way the
//! CLI does (compile → save → load → simulate/deploy) plus the raw
//! streaming DES engine at the ≥1M-arrival scale it was built for.

use std::fs;

use pipeit::cluster::{
    cluster_arrivals, simulate_cluster_streams, BoardSpec, ClusterPlan,
    ClusterServeOptions, ClusterSpec, DispatchPolicy,
};
use pipeit::config::Config;
use pipeit::reports::render_cluster;
use pipeit::simulator::arrivals::poisson_arrivals;
use pipeit::tenancy::TenantSpec;

fn compile(boards: Vec<BoardSpec>, net: &str, rate_hz: f64) -> ClusterPlan {
    let spec = ClusterSpec {
        boards,
        workloads: vec![TenantSpec::new(net, rate_hz)],
        max_replicas: 2,
    };
    ClusterPlan::compile(&spec, &Config::default()).unwrap()
}

fn p99(mut latencies: Vec<f64>) -> f64 {
    assert!(!latencies.is_empty());
    latencies.sort_by(f64::total_cmp);
    latencies[(latencies.len() - 1) * 99 / 100]
}

#[test]
fn same_seed_des_runs_are_bit_identical_on_a_compiled_plan() {
    let cp = compile(
        vec![BoardSpec::new(4, 4), BoardSpec::new(2, 6)],
        "alexnet",
        90.0,
    );
    let opts = ClusterServeOptions {
        images: 2000,
        policy: DispatchPolicy::PowerOfTwo,
        ..Default::default()
    };
    let a = cp.simulate(&opts).unwrap();
    let b = cp.simulate(&opts).unwrap();
    assert_eq!(a, b, "same plan, same seed, same options must be bit-identical");
    assert_eq!(a.images + a.shed, 2000);
}

#[test]
fn streaming_engine_digests_a_million_arrivals_deterministically() {
    // Two synthetic single-stage boards, offered slightly above their
    // joint capacity so the admission path (queues, shedding, fallback)
    // stays hot for the whole run.
    let board_fleets = vec![
        vec![vec![vec![0.0004]]], // 2500 imgs/s
        vec![vec![vec![0.0010]]], // 1000 imgs/s
    ];
    let weights = [2500.0, 1000.0];
    let up = [true, true];
    let arrivals: Vec<(f64, usize)> =
        (0..1_000_000).map(|i| (i as f64 * 2.5e-4, 0)).collect(); // 4000/s
    let run = || {
        simulate_cluster_streams(
            &board_fleets,
            &weights,
            &up,
            &arrivals,
            DispatchPolicy::PowerOfTwo,
            2,
            8,
            99,
        )
        .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "1M-arrival DES must be bit-identical run to run");
    let admitted: usize = a.iter().map(|o| o.admitted).sum();
    let shed: usize = a.iter().map(|o| o.shed).sum();
    assert_eq!(admitted + shed, 1_000_000, "front door lost items");
    assert!(shed > 0, "offered 4000/s over ~3500/s capacity must shed");
}

#[test]
fn saturated_heterogeneous_fleet_serves_90pct_of_summed_eq12_capacity() {
    let boards = vec![BoardSpec::new(4, 4), BoardSpec::new(2, 6), BoardSpec::new(4, 2)];
    let mut cp = compile(boards, "alexnet", 1.0);
    let capacity = cp.capacity();
    cp.workloads[0].rate_hz = 3.0 * capacity; // saturate the whole fleet
    let opts = ClusterServeOptions {
        images: 4000,
        policy: DispatchPolicy::LeastOutstanding,
        ..Default::default()
    };
    let report = cp.simulate(&opts).unwrap();
    assert!(report.shed > 0, "3x overload must shed");
    assert!(
        report.throughput >= 0.90 * capacity,
        "served {:.2} imgs/s < 90% of the fleet's Eq. 12 capacity {:.2}",
        report.throughput,
        capacity
    );
    assert!(
        report.throughput <= capacity * 1.05,
        "served {:.2} imgs/s exceeds Eq. 12 capacity {:.2}",
        report.throughput,
        capacity
    );
}

#[test]
fn p2c_beats_round_robin_p99_on_an_asymmetric_board_mix() {
    // One fast board (100 imgs/s) next to one 8x slower (12.5 imgs/s),
    // offered 60/s: blind round-robin drives half the traffic into the
    // slow board's queue; capacity-weighted p2c mostly avoids it.
    let board_fleets = vec![vec![vec![vec![0.01]]], vec![vec![vec![0.08]]]];
    let weights = [100.0, 12.5];
    let up = [true, true];
    let arrivals: Vec<(f64, usize)> =
        poisson_arrivals(60.0, 4000, 11).into_iter().map(|t| (t, 0)).collect();
    let run = |policy| {
        let outcomes = simulate_cluster_streams(
            &board_fleets,
            &weights,
            &up,
            &arrivals,
            policy,
            2,
            8,
            7,
        )
        .unwrap();
        let admitted: usize = outcomes.iter().map(|o| o.admitted).sum();
        let shed: usize = outcomes.iter().map(|o| o.shed).sum();
        assert_eq!(admitted + shed, 4000);
        p99(outcomes.into_iter().flat_map(|o| o.latencies).collect())
    };
    let rr = run(DispatchPolicy::RoundRobin);
    let p2c = run(DispatchPolicy::PowerOfTwo);
    assert!(
        p2c < rr,
        "p2c p99 {p2c:.3}s must beat round-robin p99 {rr:.3}s on an \
         asymmetric mix"
    );
}

#[test]
fn low_and_p2c_never_shed_while_any_admission_queue_has_capacity() {
    // Three glacial boards: nothing completes during the burst, so every
    // admission after the first per board sits in that board's queue. A
    // burst of exactly boards x admission_cap items must always fit.
    let board_fleets = vec![
        vec![vec![vec![100.0]]],
        vec![vec![vec![100.0]]],
        vec![vec![vec![100.0]]],
    ];
    let weights = [1.0, 1.0, 1.0];
    let up = [true, true, true];
    let admission_cap = 4;
    let burst: Vec<(f64, usize)> = (0..3 * admission_cap).map(|_| (0.0, 0)).collect();
    for policy in [DispatchPolicy::LeastOutstanding, DispatchPolicy::PowerOfTwo] {
        let outcomes = simulate_cluster_streams(
            &board_fleets,
            &weights,
            &up,
            &burst,
            policy,
            2,
            admission_cap,
            5,
        )
        .unwrap();
        let shed: usize = outcomes.iter().map(|o| o.shed).sum();
        assert_eq!(
            shed, 0,
            "{policy:?} shed from a burst that fits the fleet's queues"
        );
    }
    // And the complementary bound: each board admits at most
    // admission_cap + 1 from a t=0 burst (the in-service item does not
    // count against the queue), so 16 offered to 3 boards sheds exactly 1.
    let over: Vec<(f64, usize)> = (0..16).map(|_| (0.0, 0)).collect();
    let outcomes = simulate_cluster_streams(
        &board_fleets,
        &weights,
        &up,
        &over,
        DispatchPolicy::LeastOutstanding,
        2,
        admission_cap,
        5,
    )
    .unwrap();
    let shed: usize = outcomes.iter().map(|o| o.shed).sum();
    assert_eq!(shed, 1, "overflow past every queue must shed, and only then");
    for o in &outcomes {
        assert_eq!(o.admitted, admission_cap + 1);
    }
}

#[test]
fn disabling_a_board_degrades_gracefully() {
    let boards = vec![BoardSpec::new(4, 4), BoardSpec::new(2, 6), BoardSpec::new(4, 2)];
    let mut cp = compile(boards, "squeezenet", 1.0);
    cp.workloads[0].rate_hz = 1.5 * cp.capacity();
    let down = cp.boards[1].name.clone();
    let opts = ClusterServeOptions {
        images: 1500,
        disabled: vec![down],
        ..Default::default()
    };
    let report = cp.simulate(&opts).unwrap();
    let dead = &report.boards[1];
    assert!(!dead.up);
    assert_eq!(dead.offered + dead.admitted + dead.shed, 0);
    assert_eq!(report.images + report.shed, 1500, "conservation across the fleet");
    for b in [&report.boards[0], &report.boards[2]] {
        assert!(b.admitted > 0, "surviving board {} absorbed nothing", b.name);
    }
    let rendered = render_cluster(&report);
    assert!(rendered.contains("[down]"), "report must mark the dead board");

    // Killing the whole fleet is an error, not an empty report.
    let all = cp.boards.iter().map(|b| b.name.clone()).collect();
    let err = cp
        .simulate(&ClusterServeOptions { disabled: all, ..Default::default() })
        .unwrap_err();
    assert!(err.to_string().contains("every board is disabled"));
}

#[test]
fn cluster_plan_roundtrip_is_lossless_and_simulates_bit_identically() {
    let boards = vec![
        BoardSpec::new(4, 4),
        BoardSpec { seed: Some(11), ..BoardSpec::new(2, 6) },
    ];
    let cp = compile(boards, "alexnet", 120.0);
    let path = std::env::temp_dir()
        .join(format!("pipeit-cluster-roundtrip-{}.json", std::process::id()));
    cp.save(&path).unwrap();
    let loaded = ClusterPlan::load(&path).unwrap();
    fs::remove_file(&path).ok();
    assert_eq!(loaded, cp, "save -> load must be lossless");
    let opts = ClusterServeOptions { images: 1200, ..Default::default() };
    assert_eq!(
        loaded.simulate(&opts).unwrap(),
        cp.simulate(&opts).unwrap(),
        "a loaded plan must simulate bit-identically to the compiled one"
    );
}

#[test]
fn oversized_seeds_are_rejected_at_parse_and_at_load() {
    // At the CLI parse boundary...
    let err = BoardSpec::parse("cores=4+4,seed=9007199254740992").unwrap_err();
    assert!(err.to_string().contains("2^53"), "parse error: {err:#}");
    // ...and again at the artifact load boundary, in case the JSON was
    // written by hand or by a future buggy tool.
    let mut cp = compile(vec![BoardSpec::new(4, 4)], "alexnet", 30.0);
    cp.boards[0].seed = Some(1u64 << 53);
    let path = std::env::temp_dir()
        .join(format!("pipeit-cluster-badseed-{}.json", std::process::id()));
    cp.save(&path).unwrap();
    let err = ClusterPlan::load(&path).unwrap_err();
    fs::remove_file(&path).ok();
    assert!(err.to_string().contains("2^53"), "load error: {err:#}");
}

#[test]
fn default_board_seeds_give_each_board_its_own_arrival_stream() {
    // Two identical boards, identical shares: with the base + 7919*i
    // per-board seed derivation their Poisson components must differ, so
    // the merged schedule is NOT made of duplicated timestamps.
    let cp = compile(vec![BoardSpec::new(4, 4), BoardSpec::new(4, 4)], "alexnet", 60.0);
    assert!((cp.boards[0].rate_share - cp.boards[1].rate_share).abs() < 1e-9);
    let schedule =
        cluster_arrivals(&cp, &ClusterServeOptions { images: 1000, ..Default::default() });
    assert_eq!(schedule.len(), 1000);
    let mut times: Vec<f64> = schedule.iter().map(|a| a.0).collect();
    times.sort_by(f64::total_cmp);
    times.dedup();
    assert!(
        times.len() > 900,
        "identical per-board streams would collapse to duplicate pairs \
         ({} unique of 1000)",
        times.len()
    );
}
