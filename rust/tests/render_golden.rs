//! Golden snapshot tests for report rendering (ISSUE 5 satellite):
//! `render_serve`, `render_multi_serve`, `render_bench` and
//! `render_bench_compare` are compared against checked-in fixtures under
//! `tests/golden/`, so any table-format drift is a reviewed diff instead
//! of silent churn. Regenerate intentionally with
//! `UPDATE_GOLDEN=1 cargo test --test render_golden`.
//!
//! Inputs are hand-built literals (no searches, no RNG beyond degenerate
//! bootstrap inputs), so the rendered bytes depend only on the format
//! strings under test.

use std::path::PathBuf;

use pipeit::api::{
    AdaptationEvent, LatencyReport, ReplicaReport, ServeMode, ServeReport, StageReport,
};
use pipeit::harness::{
    BenchComparison, BenchHistory, BenchReport, HistoryEntry, SampleStats,
    ScenarioDiff, ScenarioResult, Verdict,
};
use pipeit::obs::{AttribReport, LogHist, MetricsSnapshot, StageAttrib};
use pipeit::reports::{
    render_attrib, render_bench, render_bench_compare, render_history,
    render_metrics, render_multi_serve, render_serve,
};
use pipeit::tenancy::{MultiServeMode, MultiServeReport, TenantReport};

fn assert_golden(name: &str, actual: &str) {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join(name);
    if std::env::var_os("UPDATE_GOLDEN").is_some() {
        std::fs::write(&path, actual).expect("golden fixture written");
        return;
    }
    let expected = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing golden fixture {name}: {e}"));
    assert_eq!(
        expected, actual,
        "rendered output drifted from tests/golden/{name}; if the change is \
         intentional, regenerate with UPDATE_GOLDEN=1 and review the diff"
    );
}

#[test]
fn render_serve_matches_golden() {
    let report = ServeReport {
        mode: ServeMode::Des,
        network: "alexnet".into(),
        images: 200,
        wall_s: 12.5,
        throughput: 16.0,
        predicted_throughput: 16.4,
        latency: Some(LatencyReport { p50: 0.12, p95: 0.15, p99: 0.18 }),
        replicas: vec![ReplicaReport {
            pipeline: "B4-s4".into(),
            allocation: "[1,9] - [10,11]".into(),
            dispatched: 200,
            throughput: 16.0,
            utilization: 0.8,
            bottleneck: Some(0),
            stages: vec![
                StageReport {
                    name: "stage0".into(),
                    items: 200,
                    busy_s: 10.0,
                    utilization: 0.8,
                },
                StageReport {
                    name: "stage1".into(),
                    items: 200,
                    busy_s: 5.0,
                    utilization: 0.4,
                },
            ],
        }],
        adaptations: vec![AdaptationEvent {
            at_s: 3.25,
            after_images: 80,
            disturbance: "big-cluster slowdown x2.00".into(),
            from: "B4-s4".into(),
            to: "B2-s4".into(),
            predicted_throughput: 12.5,
        }],
        metrics: None,
        attrib: None,
    };
    assert_golden("render_serve.txt", &render_serve(&report));
}

#[test]
fn render_multi_serve_matches_golden() {
    let report = MultiServeReport {
        mode: MultiServeMode::Des,
        wall_s: 10.0,
        images: 298,
        shed: 202,
        weighted_throughput: 29.6,
        board_utilization: 0.83,
        tenants: vec![
            TenantReport {
                name: "alexnet".into(),
                network: "alexnet".into(),
                budget: "3B+1s".into(),
                pipeline: "B2-s1 | B1".into(),
                rate_hz: 30.0,
                weight: 1.0,
                offered: 300,
                admitted: 298,
                shed: 2,
                throughput: 29.6,
                capacity: 41.0,
                latency: Some(LatencyReport { p50: 0.02, p95: 0.04, p99: 0.05 }),
                p99_sla_s: Some(0.08),
                sla_ok: Some(true),
                utilization: 0.71,
            },
            // The fully-shed extreme: zero admitted, no latency evidence.
            TenantReport {
                name: "squeezenet".into(),
                network: "squeezenet".into(),
                budget: "1B+3s".into(),
                pipeline: "s3".into(),
                rate_hz: 60.0,
                weight: 2.0,
                offered: 200,
                admitted: 0,
                shed: 200,
                throughput: 0.0,
                capacity: 18.75,
                latency: None,
                p99_sla_s: None,
                sla_ok: None,
                utilization: 0.0,
            },
        ],
        metrics: None,
        attrib: None,
    };
    assert_golden("render_multi_serve.txt", &render_multi_serve(&report));
}

fn bench_fixture() -> BenchReport {
    BenchReport {
        suite: "quick".into(),
        seed: 7,
        warmup: 1,
        reps: 5,
        recorded_rep: Some(4),
        scenarios: vec![
            ScenarioResult {
                name: "pipelined/alexnet".into(),
                mode: "pipelined".into(),
                backend: "des".into(),
                unit: "imgs/s".into(),
                higher_is_better: true,
                samples: vec![16.0; 4],
                stats: SampleStats {
                    n: 4,
                    rejected: 0,
                    median: 16.0,
                    mean: 16.0,
                    mad: 0.0,
                    ci_lo: 16.0,
                    ci_hi: 16.0,
                },
                host_s: 0.2,
                metrics: None,
            },
            ScenarioResult {
                name: "multi/alexnet30+squeezenet60".into(),
                mode: "multi-tenant".into(),
                backend: "wall".into(),
                unit: "imgs/s".into(),
                higher_is_better: true,
                // 6 raw samples; MAD rejection drops the 99.0 outlier, so
                // n=5(-1), median 12.34 and MAD 0.16 are the true stats of
                // the kept subset (the snapshot is a reachable state).
                samples: vec![12.1, 12.34, 12.6, 12.5, 12.2, 99.0],
                stats: SampleStats {
                    n: 5,
                    rejected: 1,
                    median: 12.34,
                    mean: 12.348,
                    mad: 0.16,
                    ci_lo: 12.1,
                    ci_hi: 12.6,
                },
                host_s: 1.5,
                metrics: None,
            },
            ScenarioResult {
                name: "explore_64_pipelines_alexnet".into(),
                mode: "micro".into(),
                backend: "host".into(),
                unit: "s".into(),
                higher_is_better: false,
                samples: Vec::new(),
                stats: SampleStats {
                    n: 200,
                    rejected: 3,
                    median: 0.00125,
                    mean: 0.0013,
                    mad: 0.00005,
                    ci_lo: 0.0012,
                    ci_hi: 0.0013,
                },
                host_s: 0.7,
                metrics: None,
            },
        ],
    }
}

#[test]
fn render_bench_matches_golden() {
    assert_golden("render_bench.txt", &render_bench(&bench_fixture()));
}

#[test]
fn render_bench_compare_matches_golden() {
    let cmp = BenchComparison {
        diffs: vec![
            ScenarioDiff {
                name: "pipelined/alexnet".into(),
                mode: "pipelined".into(),
                backend: "des".into(),
                unit: "imgs/s".into(),
                old_median: 16.0,
                new_median: 14.4,
                rel_delta: -0.1,
                verdict: Verdict::Regressed,
            },
            ScenarioDiff {
                name: "multi/alexnet30+squeezenet60".into(),
                mode: "multi-tenant".into(),
                backend: "wall".into(),
                unit: "imgs/s".into(),
                old_median: 12.34,
                new_median: 12.34,
                rel_delta: 0.0,
                verdict: Verdict::Unchanged,
            },
        ],
        added: vec!["des/replicated/squeezenet".into()],
        removed: vec!["host/explore_64_pipelines_alexnet".into()],
    };
    assert_golden("render_bench_compare.txt", &render_bench_compare(&cmp));
}

#[test]
fn render_metrics_matches_golden() {
    let mut m = MetricsSnapshot::default();
    m.counters.insert("admitted".into(), 210);
    m.counters.insert("shed".into(), 10);
    m.counters.insert("departed".into(), 200);
    m.gauges.insert("wall_s".into(), 12.5);
    m.gauges.insert("queue_depth_peak/g0".into(), 3.0);
    m.gauges.insert("queue_depth_peak/g1".into(), 5.0);
    m.gauges.insert("occupancy/g0r0s0".into(), 0.8);
    m.gauges.insert("occupancy/g0r0s1".into(), 0.4);
    m.gauges.insert("occupancy/g1r0s0".into(), 0.95);
    m.hists
        .insert("latency".into(), LogHist::of(&[0.12, 0.15, 0.18, 0.12, 0.13]));
    m.hists
        .insert("stage_service/g0r0s0".into(), LogHist::of(&[0.05; 4]));
    m.hists
        .insert("stage_service/g0r0s1".into(), LogHist::of(&[0.025; 4]));
    m.hists
        .insert("stage_service/g1r0s0".into(), LogHist::of(&[0.06; 4]));
    assert_golden("render_metrics.txt", &render_metrics(&m));
}

#[test]
fn render_attrib_matches_golden() {
    let report = AttribReport {
        items: 200,
        shed: 10,
        front_wait_s: 0.0125,
        queue_wait_s: 0.003,
        service_s: 0.105,
        latency_s: 0.1205,
        max_abs_err_s: 2.2e-16,
        stages: vec![
            StageAttrib {
                group: 0,
                replica: 0,
                stage: 0,
                items: 200,
                observed_s: 0.0625,
                predicted_s: Some(0.061),
                residual_s: 0.0015,
                excess_s: 0.3,
            },
            StageAttrib {
                group: 0,
                replica: 0,
                stage: 1,
                items: 200,
                observed_s: 0.0425,
                predicted_s: Some(0.043),
                residual_s: -0.0005,
                excess_s: -0.1,
            },
            // Trace-only row: the plan carried no prediction here.
            StageAttrib {
                group: 1,
                replica: 0,
                stage: 0,
                items: 100,
                observed_s: 0.02,
                predicted_s: None,
                residual_s: 0.0,
                excess_s: 0.0,
            },
        ],
        annotations: vec![
            "t=3.25s after 80 imgs: big-cluster slowdown x2.00 B4-s4 -> B2-s4 \
             (pred 12.50 imgs/s)"
                .into(),
        ],
    };
    assert_golden("render_attrib.txt", &render_attrib(&report));
}

#[test]
fn render_history_matches_golden() {
    let scenario = |name: &str, backend: &str, unit: &str, median: f64| ScenarioResult {
        name: name.into(),
        mode: "pipelined".into(),
        backend: backend.into(),
        unit: unit.into(),
        higher_is_better: unit != "s",
        samples: vec![median; 3],
        stats: SampleStats {
            n: 3,
            rejected: 0,
            median,
            mean: median,
            mad: 0.0,
            ci_lo: median,
            ci_hi: median,
        },
        host_s: 0.1,
        metrics: None,
    };
    let report = |scenarios: Vec<ScenarioResult>| BenchReport {
        suite: "quick".into(),
        seed: 7,
        warmup: 1,
        reps: 3,
        recorded_rep: None,
        scenarios,
    };
    let history = BenchHistory::from_entries(vec![
        HistoryEntry {
            label: "0".into(),
            report: report(vec![
                scenario("pipelined/alexnet", "des", "imgs/s", 16.0),
                scenario("explore_64_pipelines_alexnet", "host", "s", 0.00125),
            ]),
        },
        HistoryEntry {
            label: "1".into(),
            report: report(vec![scenario("pipelined/alexnet", "des", "imgs/s", 17.6)]),
        },
        HistoryEntry {
            label: "ci".into(),
            report: report(vec![scenario(
                "explore_64_pipelines_alexnet",
                "host",
                "s",
                0.0011,
            )]),
        },
    ]);
    assert_golden("render_history.txt", &render_history(&history));
}
