//! Differential DES / wall-clock conformance suite (ISSUE 5 satellite):
//! for EVERY scenario in the harness registry, the discrete-event twin and
//! the wall-clock (simulated-time thread executor) twin must agree on the
//! throughput metric within the scenario's declared tolerance, and neither
//! may exceed the design's Eq. 12 capacity. This is the standing oracle
//! that keeps the twins honest as the codebase keeps being refactored: a
//! change that drifts one executor away from the other fails here, not in
//! a paper table three PRs later.

use pipeit::harness::{registry, Backend};

/// Headroom over the Eq. 12 bound: the metric is measured over a finite
/// stream (fill/drain transients only LOWER it), so anything beyond a few
/// percent above capacity is a conservation bug, not noise.
const CAPACITY_HEADROOM: f64 = 1.05;

#[test]
fn every_scenario_des_and_wall_twins_agree_within_declared_tolerance() {
    let mut failures = Vec::new();
    for s in registry() {
        if s.des_only {
            // Throughput-stress entries have no wall twin (a 1M-item
            // time-scaled sleep run); the DES side is covered by the
            // capacity test below and the event-core suite.
            continue;
        }
        let des = s
            .run(Backend::Des, 7)
            .unwrap_or_else(|e| panic!("{}: DES run failed: {e:#}", s.name));
        let wall = s
            .run(Backend::Wall, 7)
            .unwrap_or_else(|e| panic!("{}: wall run failed: {e:#}", s.name));
        assert!(des > 0.0, "{}: DES metric must be positive", s.name);
        assert!(wall > 0.0, "{}: wall metric must be positive", s.name);
        let rel = (wall - des).abs() / des;
        if rel > s.tolerance {
            failures.push(format!(
                "{}: DES {des:.2} vs wall {wall:.2} imgs/s (rel {rel:.3} > tolerance {})",
                s.name, s.tolerance
            ));
        }
    }
    assert!(
        failures.is_empty(),
        "twins disagree beyond declared tolerances:\n{}",
        failures.join("\n")
    );
}

#[test]
fn every_scenario_respects_eq12_capacity_on_both_twins() {
    for s in registry() {
        let cap = s
            .capacity()
            .unwrap_or_else(|e| panic!("{}: capacity failed: {e:#}", s.name));
        assert!(cap > 0.0, "{}: capacity must be positive", s.name);
        let des = s.run(Backend::Des, 7).expect("DES run");
        assert!(
            des <= cap * CAPACITY_HEADROOM,
            "{}: DES {des:.2} imgs/s exceeds Eq. 12 capacity {cap:.2}",
            s.name
        );
        if s.des_only {
            continue; // no wall twin for throughput-stress entries
        }
        let wall = s.run(Backend::Wall, 7).expect("wall run");
        assert!(
            wall <= cap * CAPACITY_HEADROOM,
            "{}: wall {wall:.2} imgs/s exceeds Eq. 12 capacity {cap:.2}",
            s.name
        );
    }
}

#[test]
fn registry_spans_the_required_modes_and_is_twin_complete() {
    let reg = registry();
    assert!(reg.len() >= 12, "registry shrank to {} scenarios", reg.len());
    let mut modes: Vec<&str> = reg.iter().map(|s| s.mode).collect();
    modes.sort_unstable();
    modes.dedup();
    for required in
        ["serial", "pipelined", "replicated", "adaptive", "multi-tenant", "cluster"]
    {
        assert!(modes.contains(&required), "mode {required:?} missing from {modes:?}");
    }
    // Twin-complete: every scenario declares a finite positive tolerance —
    // the contract the differential assertions above enforce.
    for s in &reg {
        assert!(
            s.tolerance > 0.0 && s.tolerance < 1.0,
            "{}: tolerance {} is not a usable bound",
            s.name,
            s.tolerance
        );
    }
}
