//! Acceptance suite for multi-tenant co-serving (DESIGN.md §10):
//!
//! For two zoo networks co-served in the DES, the `explore_joint` split
//! (a) meets every declared SLA when one is feasible, (b) achieves ≥ 90%
//! of the sum of each tenant's isolated full-board throughput scaled by
//! its core share, and (c) strictly beats a naive equal-split baseline on
//! weighted throughput for at least one asymmetric rate mix. A saved
//! `MultiPlan` reloads byte-identically in reported per-tenant pipelines,
//! allocations, and predicted throughput, and simulates identically.
//!
//! Everything here is deterministic: measured time matrices, seeded
//! Poisson streams, and an exact DES recurrence.

use pipeit::cnn::zoo;
use pipeit::config::Config;
use pipeit::dse;
use pipeit::perfmodel::TimeMatrix;
use pipeit::tenancy::{MultiPlan, MultiServeOptions, TenantSpec};

const NET_A: &str = "alexnet";
const NET_B: &str = "squeezenet";

fn isolated_full_board(net: &str) -> f64 {
    let cfg = Config::default();
    let tm = TimeMatrix::measured(&cfg.platform, &zoo::by_name(net).unwrap());
    dse::explore_replicated(&tm, 4, 4, 8).throughput
}

fn des_opts(images: usize) -> MultiServeOptions {
    MultiServeOptions { images, queue_cap: 2, admission_cap: 8, ..Default::default() }
}

/// (a) Declare SLAs calibrated from an undeclared pre-run (2.5x the
/// observed p99): the joint DSE must produce a split whose DES co-serving
/// meets every declared SLA.
#[test]
fn joint_split_meets_every_declared_sla_when_feasible() {
    let cfg = Config::default();
    let (tp_a, tp_b) = (isolated_full_board(NET_A), isolated_full_board(NET_B));
    let rates = [0.35 * tp_a, 0.35 * tp_b];

    // Pre-run without SLAs to observe achievable p99s under this load.
    let specs0 = [
        TenantSpec::new(NET_A, rates[0]),
        TenantSpec::new(NET_B, rates[1]),
    ];
    let mp0 = MultiPlan::compile(&specs0, &cfg, 4).unwrap();
    let pre = mp0.simulate(&des_opts(1500)).unwrap();
    let slas: Vec<f64> = pre
        .tenants
        .iter()
        .map(|t| 2.5 * t.latency.expect("admitted items").p99)
        .collect();
    assert!(slas.iter().all(|s| s.is_finite() && *s > 0.0));

    // Re-plan with the SLAs declared; the co-simulation must meet them all.
    let specs1 = [
        TenantSpec::new(NET_A, rates[0]).with_sla(slas[0]),
        TenantSpec::new(NET_B, rates[1]).with_sla(slas[1]),
    ];
    let mp1 = MultiPlan::compile(&specs1, &cfg, 4).unwrap();
    let report = mp1.simulate(&des_opts(1500)).unwrap();
    let (met, declared) = report.sla_counts();
    assert_eq!(declared, 2);
    for (t, sla) in report.tenants.iter().zip(&slas) {
        let p99 = t.latency.expect("admitted items").p99;
        assert!(
            p99 <= *sla,
            "tenant {}: DES p99 {:.1}ms violates its declared SLA {:.1}ms",
            t.name,
            p99 * 1e3,
            sla * 1e3
        );
    }
    assert_eq!(met, declared, "render/report must agree with the raw latencies");
}

/// (b) Under saturating demand, the joint split's aggregate capacity stays
/// within 90% of each tenant's isolated full-board throughput scaled by
/// its core share — splitting the board loses at most the quantization
/// slack, and the DES corroborates the predicted capacities.
#[test]
fn joint_capacity_is_at_least_90pct_of_share_scaled_isolated() {
    let cfg = Config::default();
    let saturating = 1e9;
    let specs = [
        TenantSpec::new(NET_A, saturating),
        TenantSpec::new(NET_B, saturating),
    ];
    let mp = MultiPlan::compile(&specs, &cfg, 4).unwrap();

    let isolated = [isolated_full_board(NET_A), isolated_full_board(NET_B)];
    let total_cores = (mp.big + mp.small) as f64;
    let mut bound = 0.0;
    let mut capacity = 0.0;
    for (t, iso) in mp.tenants.iter().zip(&isolated) {
        let share = (t.plan.big + t.plan.small) as f64 / total_cores;
        bound += iso * share;
        capacity += t.plan.throughput;
    }
    assert!(
        capacity >= 0.9 * bound,
        "joint capacity {capacity:.2} imgs/s below 90% of the share-scaled \
         isolated sum {bound:.2}"
    );

    // DES corroboration: with a wide-open front door the observed served
    // rate approaches the predicted capacity.
    let opts = MultiServeOptions {
        images: 2000,
        admission_cap: 100_000,
        ..Default::default()
    };
    let report = mp.simulate(&opts).unwrap();
    let observed: f64 = report.tenants.iter().map(|t| t.throughput).sum();
    assert!(
        observed >= 0.9 * capacity,
        "DES served {observed:.2} imgs/s far below predicted capacity {capacity:.2}"
    );
}

/// (c) For at least one asymmetric rate mix, the joint split strictly
/// beats the naive equal split (half the board per tenant) on weighted
/// served throughput.
#[test]
fn joint_strictly_beats_naive_equal_split_on_an_asymmetric_mix() {
    let cfg = Config::default();
    let (tp_a, tp_b) = (isolated_full_board(NET_A), isolated_full_board(NET_B));
    let equal_cap = |net: &str| {
        let tm = TimeMatrix::measured(&cfg.platform, &zoo::by_name(net).unwrap());
        dse::explore_replicated(&tm, 2, 2, 4).throughput
    };
    let (eq_a, eq_b) = (equal_cap(NET_A), equal_cap(NET_B));

    let mut strict_win = false;
    for (fa, fb) in [(0.1, 1.5), (1.5, 0.1), (0.2, 2.0), (2.0, 0.2)] {
        let rates = [fa * tp_a, fb * tp_b];
        let specs = [
            TenantSpec::new(NET_A, rates[0]),
            TenantSpec::new(NET_B, rates[1]),
        ];
        let mp = MultiPlan::compile(&specs, &cfg, 4).unwrap();
        let naive = rates[0].min(eq_a) + rates[1].min(eq_b);
        assert!(
            mp.weighted_throughput >= naive - 1e-9,
            "mix ({fa},{fb}): joint {:.2} lost to the equal split {naive:.2}",
            mp.weighted_throughput
        );
        if mp.weighted_throughput > naive + 1e-6 {
            strict_win = true;
        }
    }
    assert!(
        strict_win,
        "no asymmetric mix produced a strict win over the equal split"
    );
}

/// `MultiPlan` save → load → simulate: the reloaded artifact is identical
/// in per-tenant pipelines, allocations, and predicted throughput, and its
/// co-simulation reproduces the original bit for bit.
#[test]
fn multiplan_save_load_simulate_is_identical() {
    let cfg = Config::default();
    let specs = [
        TenantSpec::new(NET_A, 6.0).with_sla(5.0),
        TenantSpec::new(NET_B, 12.0).with_weight(2.0),
    ];
    let mp = MultiPlan::compile(&specs, &cfg, 4).unwrap();

    let path = std::env::temp_dir().join("pipeit_multi_tenant_accept.json");
    mp.save(&path).unwrap();
    let loaded = MultiPlan::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(mp, loaded, "the artifact must round-trip losslessly");
    for (a, b) in mp.tenants.iter().zip(&loaded.tenants) {
        assert_eq!(a.partition_display(), b.partition_display());
        for (ra, rb) in a.plan.replicas.iter().zip(&b.plan.replicas) {
            assert_eq!(ra.pipeline, rb.pipeline);
            assert_eq!(ra.allocation, rb.allocation);
            assert_eq!(ra.stage_times, rb.stage_times, "stage times must be exact");
        }
        assert_eq!(a.plan.throughput, b.plan.throughput, "predicted throughput exact");
    }
    assert_eq!(mp.weighted_throughput, loaded.weighted_throughput);

    let opts = des_opts(600);
    let r1 = mp.simulate(&opts).unwrap();
    let r2 = loaded.simulate(&opts).unwrap();
    assert_eq!(r1, r2, "simulating the reloaded plan must be identical");
}

/// The joint DSE assigns every core exactly once, and a single tenant
/// degenerates to the whole board.
#[test]
fn joint_split_is_a_partition_of_the_board() {
    let cfg = Config::default();
    let specs = [
        TenantSpec::new(NET_A, 5.0),
        TenantSpec::new(NET_B, 10.0),
    ];
    let mp = MultiPlan::compile(&specs, &cfg, 4).unwrap();
    let big: usize = mp.tenants.iter().map(|t| t.plan.big).sum();
    let small: usize = mp.tenants.iter().map(|t| t.plan.small).sum();
    assert_eq!((big, small), (mp.big, mp.small));
    assert!(mp.tenants.iter().all(|t| t.plan.big + t.plan.small >= 1));

    let solo = MultiPlan::compile(&[TenantSpec::new(NET_B, 1e9)], &cfg, 4).unwrap();
    assert_eq!(solo.tenants[0].plan.big, cfg.platform.big.cores);
    assert_eq!(solo.tenants[0].plan.small, cfg.platform.small.cores);
    let tm = TimeMatrix::measured(&cfg.platform, &zoo::by_name(NET_B).unwrap());
    let direct = dse::explore_replicated(&tm, 4, 4, 4);
    assert!((solo.tenants[0].plan.throughput - direct.throughput).abs() < 1e-9);
}

/// Overload sheds at the per-tenant front door but never silently loses
/// an arrival, and the bounded queue keeps admitted latency bounded.
#[test]
fn overload_sheds_per_tenant_and_conserves_arrivals() {
    let cfg = Config::default();
    let (tp_a, tp_b) = (isolated_full_board(NET_A), isolated_full_board(NET_B));
    // Tenant A offered 4x what the whole board could give it; B modest.
    let specs = [
        TenantSpec::new(NET_A, 4.0 * tp_a),
        TenantSpec::new(NET_B, 0.2 * tp_b),
    ];
    let mp = MultiPlan::compile(&specs, &cfg, 4).unwrap();
    let report = mp.simulate(&des_opts(1200)).unwrap();
    for t in &report.tenants {
        assert_eq!(t.admitted + t.shed, t.offered, "tenant {}", t.name);
    }
    let a = &report.tenants[0];
    let b = &report.tenants[1];
    assert!(
        a.shed * 2 > a.offered,
        "the 4x-overloaded tenant must shed most arrivals: {a:?}"
    );
    assert!(
        b.shed * 10 < b.offered,
        "the within-capacity tenant must shed at most a small fraction: {b:?}"
    );
    // Shedding bounds the admitted items' latency: the overloaded tenant's
    // p99 stays within (admission_cap + 2) service times of its slowest
    // replica rather than growing with the backlog.
    let worst_service: f64 = a.capacity.recip() * (des_opts(0).admission_cap + 2) as f64
        + mp.tenants[0]
            .plan
            .replicas
            .iter()
            .map(|r| r.stage_times.iter().sum::<f64>())
            .fold(0.0, f64::max);
    assert!(
        a.latency.unwrap().p99 <= worst_service * 4.0,
        "p99 {:.2}s not bounded (budget {:.2}s)",
        a.latency.unwrap().p99,
        worst_service * 4.0
    );
}
