//! Throttle-recovery acceptance tests for the online-adaptation subsystem
//! (DESIGN.md §9): a scripted cluster slowdown injected mid-run under
//! adaptation must be detected, recalibrated, and re-planned, with
//! post-swap sustained throughput within 10% of a plan explored directly on
//! the throttled time matrix — and strictly better than the non-adaptive
//! run under the same disturbance.
//!
//! Everything here runs in the discrete-event simulator: deterministic, no
//! threads, no wall-clock sensitivity.

use pipeit::adapt::{simulate_adaptive, AdaptOptions, ClusterThrottle, DriftConfig};
use pipeit::api::{Plan, PlanSpec, Strategy};
use pipeit::cnn::zoo;
use pipeit::config::Config;
use pipeit::perfmodel::TimeMatrix;
use pipeit::simulator::platform::CoreType;

fn setup(net: &str, strategy: Strategy) -> (Config, TimeMatrix, Plan) {
    let cfg = Config::default();
    let network = zoo::by_name(net).unwrap();
    let tm = TimeMatrix::measured(&cfg.platform, &network);
    let plan = PlanSpec::new(net).strategy(strategy).compile().unwrap();
    (cfg, tm, plan)
}

/// Open-loop twin: same disturbance script, but a drift threshold no honest
/// ratio reaches, so the controller never swaps.
fn baseline_opts(opts: &AdaptOptions) -> AdaptOptions {
    AdaptOptions {
        drift: DriftConfig { threshold: 1e12, ..opts.drift },
        ..*opts
    }
}

#[test]
fn throttle_recovery_meets_the_acceptance_criteria() {
    let (cfg, base, plan) = setup("alexnet", Strategy::Pipeline);
    let images = 600;
    let queue_cap = 2;
    // Windows are cleared per control period, so by the time per-stage
    // hysteresis confirms (>= one full period after onset) every window
    // holds only post-throttle samples: the estimated factor is exact and
    // the re-plan lands on the oracle design. interval 100 keeps the
    // per-period pipeline fill/drain transient under ~7% even for the
    // deepest 8-stage pipelines.
    let opts = AdaptOptions { interval: 100, ..AdaptOptions::default() };

    // Scripted 2x big-cluster slowdown roughly a quarter into the run.
    let throttle_at = 0.25 * images as f64 / plan.throughput;
    let script =
        [ClusterThrottle { at: throttle_at, core: CoreType::Big, factor: 2.0 }];

    let out = simulate_adaptive(
        &plan, &base, &cfg.power, &script, &opts, images, queue_cap,
    )
    .unwrap();

    // Exactly one re-plan, correctly classified; no items lost.
    assert_eq!(
        out.report.adaptations.len(),
        1,
        "expected exactly one swap: {:?}",
        out.report.adaptations
    );
    assert_eq!(out.report.images, images, "items lost across the hot-swap");
    let event = &out.report.adaptations[0];
    assert!(
        event.disturbance.contains("big-cluster slowdown"),
        "misclassified disturbance: {}",
        event.disturbance
    );
    assert!(event.at_s > throttle_at, "swap cannot precede the disturbance");

    // Recovery: post-swap sustained throughput within 10% of the oracle —
    // the same strategy search run directly on the truly throttled matrix.
    let mut throttled = base.clone();
    throttled.scale_core(CoreType::Big, 2.0);
    let oracle = plan.replan_on_matrix(&throttled, &cfg.power).unwrap();
    let post = out.post_swap_throughput();
    assert!(
        post >= 0.9 * oracle.throughput,
        "post-swap {post:.3} imgs/s below 90% of the oracle's {:.3} imgs/s",
        oracle.throughput
    );

    // Strictly better than the non-adaptive run under the same disturbance.
    let baseline = simulate_adaptive(
        &plan,
        &base,
        &cfg.power,
        &script,
        &baseline_opts(&opts),
        images,
        queue_cap,
    )
    .unwrap();
    assert!(baseline.report.adaptations.is_empty());
    assert_eq!(baseline.report.images, images);
    assert!(
        out.report.throughput > baseline.report.throughput,
        "adaptive {:.3} imgs/s must beat non-adaptive {:.3} imgs/s",
        out.report.throughput,
        baseline.report.throughput
    );
    // And the sustained post-swap rate beats the baseline's post-throttle
    // steady state (the stale design's Eq. 12 rate on the throttled matrix).
    let stale = plan.replicas[0].stage_times.clone();
    let stale_throttled: f64 = {
        // Big stages doubled: recompute the stale bottleneck under truth.
        let pipe = pipeit::dse::PipelineConfig::parse(&plan.replicas[0].pipeline).unwrap();
        let times = pipeit::dse::stage_times(&throttled, &pipe, &plan.allocation_of(0));
        assert_eq!(times.len(), stale.len());
        1.0 / times.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    };
    assert!(
        post > stale_throttled,
        "post-swap {post:.3} must beat the stale design's throttled rate {stale_throttled:.3}"
    );
}

#[test]
fn replicated_fleet_recovers_from_small_cluster_throttle() {
    let (cfg, base, plan) =
        setup("squeezenet", Strategy::Replicated { max_replicas: 2, exact: false });
    let images = 800;
    // Replicas split each period's items by dispatch share; windows are
    // cleared per period, so even the slowest replica's window is pure
    // post-throttle data by confirmation time.
    let opts = AdaptOptions { interval: 100, ..AdaptOptions::default() };

    let throttle_at = 0.2 * images as f64 / plan.throughput;
    let script =
        [ClusterThrottle { at: throttle_at, core: CoreType::Small, factor: 3.0 }];

    let out =
        simulate_adaptive(&plan, &base, &cfg.power, &script, &opts, images, 2).unwrap();
    let baseline = simulate_adaptive(
        &plan,
        &base,
        &cfg.power,
        &script,
        &baseline_opts(&opts),
        images,
        2,
    )
    .unwrap();

    assert_eq!(out.report.images, images, "items lost across the hot-swap");
    // The fleet uses the small cluster (replicated squeezenet always does),
    // so the throttle must be seen and acted on exactly once.
    assert_eq!(out.report.adaptations.len(), 1, "{:?}", out.report.adaptations);
    assert!(
        out.report.throughput > baseline.report.throughput,
        "adaptive {:.3} vs baseline {:.3}",
        out.report.throughput,
        baseline.report.throughput
    );
}

#[test]
fn adaptation_log_serializes_with_the_report() {
    let (cfg, base, plan) = setup("mobilenet", Strategy::Pipeline);
    let throttle_at = 0.2 * 400.0 / plan.throughput;
    let script =
        [ClusterThrottle { at: throttle_at, core: CoreType::Big, factor: 2.5 }];
    let out = simulate_adaptive(
        &plan,
        &base,
        &cfg.power,
        &script,
        &AdaptOptions::default(),
        400,
        2,
    )
    .unwrap();
    assert!(!out.report.adaptations.is_empty());
    let text = out.report.to_json().to_string();
    let j = pipeit::util::json::Json::parse(&text).expect("metrics JSON parses");
    let adap = j.req("adaptations").unwrap().as_arr().unwrap();
    assert_eq!(adap.len(), out.report.adaptations.len());
    assert!(adap[0]
        .req("disturbance")
        .unwrap()
        .as_str()
        .unwrap()
        .contains("slowdown"));
    // The rendered report shows the swap too.
    let rendered = pipeit::reports::render_serve(&out.report);
    assert!(rendered.contains("adapt      :"), "{rendered}");
}
