//! Integration: the Plan → Deploy lifecycle across the whole framework.
//! The contract under test: a plan explored once, saved to JSON, and
//! reloaded behaves identically to the in-process explore + serve path —
//! same pipeline, same allocation, same predicted throughput, same DES
//! results.

use std::process::Command;

use pipeit::api::{Plan, PlanSpec, Strategy};
use pipeit::cnn::zoo;
use pipeit::config::Config;
use pipeit::dse;
use pipeit::perfmodel::TimeMatrix;
use pipeit::simulator::pipeline_sim;

fn pipeit(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_pipeit"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("binary runs");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn saved_plan_behaves_identically_to_the_original() {
    let plan = PlanSpec::new("squeezenet")
        .strategy(Strategy::Replicated { max_replicas: 4, exact: false })
        .compile()
        .unwrap();
    let dir = std::env::temp_dir().join("pipeit_plan_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("p.json");
    plan.save(&path).unwrap();
    let loaded = Plan::load(&path).unwrap();
    assert_eq!(plan, loaded, "save -> load must be lossless");

    // Identical behavior: the DES over the loaded plan reproduces the DES
    // over the freshly compiled one bit-for-bit (stage times round-trip
    // exactly through the JSON).
    let a = plan.simulate(800, 2).unwrap();
    let b = loaded.simulate(800, 2).unwrap();
    assert_eq!(a, b);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn plan_facade_matches_in_process_explore_path() {
    // The `plan` -> `serve --plan` path must reproduce what the in-process
    // `explore` + `serve --net` path computes: same pipeline, same
    // allocation, and the same predicted throughput.
    let cfg = Config::default();
    let tm = TimeMatrix::measured(&cfg.platform, &zoo::by_name("alexnet").unwrap());
    let design = dse::explore_exact(&tm, 4, 4, 2).expect("2-replica design exists");

    let plan = PlanSpec::new("alexnet")
        .strategy(Strategy::Replicated { max_replicas: 2, exact: true })
        .compile()
        .unwrap();
    assert_eq!(plan.num_replicas(), 2);
    for (pr, dr) in plan.replicas.iter().zip(&design.replicas) {
        assert_eq!(pr.pipeline, dr.point.pipeline.to_string());
        assert_eq!(pr.allocation, dr.point.allocation.ranges);
        assert!((pr.throughput - dr.point.throughput).abs() < 1e-12);
        assert_eq!((pr.big, pr.small), (dr.budget.big, dr.budget.small));
    }
    assert!((plan.throughput - design.throughput).abs() < 1e-12);

    // And the plan's DES backend agrees with the raw simulator on the
    // design's stage times (within float identity — same inputs).
    let direct = pipeline_sim::simulate_replicated(&design.stage_times(&tm), 500, 2);
    let via_plan = plan.simulate(500, 2).unwrap();
    let rel = (via_plan.throughput - direct.throughput).abs() / direct.throughput;
    assert!(
        rel < 1e-9,
        "plan DES {} vs direct DES {}",
        via_plan.throughput,
        direct.throughput
    );
}

#[test]
fn cli_plan_serve_simulate_lifecycle() {
    let dir = std::env::temp_dir().join("pipeit_plan_cli");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan.json");
    let p = path.to_str().unwrap();

    let (ok, text) = pipeit(&["plan", "--net", "squeezenet", "--out", p]);
    assert!(ok, "{text}");
    assert!(text.contains("plan saved"), "{text}");
    assert!(text.contains("pipeline"), "{text}");

    let (ok, text) = pipeit(&["simulate", "--plan", p, "--images", "300"]);
    assert!(ok, "{text}");
    assert!(text.contains("sim tp"), "{text}");
    assert!(text.contains("bottleneck"), "{text}");

    let (ok, text) = pipeit(&[
        "serve", "--plan", p, "--images", "12", "--time-scale", "0.02",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("fleet"), "{text}");
    assert!(text.contains("aggregate"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_plan_replicas_roundtrip_preserves_partition() {
    let dir = std::env::temp_dir().join("pipeit_plan_cli_replicas");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fleet.json");
    let p = path.to_str().unwrap();

    let (ok, text) = pipeit(&["plan", "--net", "alexnet", "--replicas", "2", "--out", p]);
    assert!(ok, "{text}");

    let loaded = Plan::load(&path).unwrap();
    assert_eq!(loaded.num_replicas(), 2);
    let cfg = Config::default();
    let tm = TimeMatrix::measured(&cfg.platform, &zoo::by_name("alexnet").unwrap());
    let design = dse::explore_exact(&tm, 4, 4, 2).unwrap();
    assert_eq!(loaded.partition_display(), design.partition_display());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_rejects_option_without_value() {
    // The Args::parse hardening: `--net --replicas 2` used to silently
    // degrade --net to a flag; now it is a loud parse error.
    let (ok, text) = pipeit(&["explore", "--net", "--replicated"]);
    assert!(!ok);
    assert!(text.contains("--net expects a value"), "{text}");
}
