//! Minimal offline stand-in for the `once_cell` crate.
//!
//! Implements only `once_cell::sync::Lazy` (the subset the workspace's
//! tests use for shared fixtures), built on `std::sync::OnceLock`. The
//! initializer is `F: Fn() -> T` rather than `FnOnce` — `OnceLock`
//! guarantees it runs at most once, and every call site passes a
//! non-capturing closure, which coerces to the default `fn() -> T`.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access, usable in `static` items.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy { cell: OnceLock::new(), init }
        }
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        /// Force initialization and return the value.
        pub fn force(this: &Lazy<T, F>) -> &T {
            this.cell.get_or_init(|| (this.init)())
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::Lazy;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static CALLS: AtomicUsize = AtomicUsize::new(0);
    static VALUE: Lazy<u64> = Lazy::new(|| {
        CALLS.fetch_add(1, Ordering::SeqCst);
        40 + 2
    });

    #[test]
    fn initializes_once_in_static() {
        assert_eq!(*VALUE, 42);
        assert_eq!(*VALUE, 42);
        assert_eq!(CALLS.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn works_with_capturing_closure_local() {
        let base = 10;
        let lazy = Lazy::new(move || base * 3);
        assert_eq!(*lazy, 30);
    }
}
