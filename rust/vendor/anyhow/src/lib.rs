//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! implements exactly the subset of the `anyhow` 1.x API the workspace
//! uses: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the [`anyhow!`], [`bail!`] and [`ensure!`]
//! macros. Error values carry a context chain that renders in `Debug`
//! output the way `anyhow` renders it (message, then `Caused by:` frames),
//! so `fn main() -> anyhow::Result<()>` prints readable failures.
//!
//! Not implemented (unused here): backtraces, downcasting, `Error::chain`.

use std::fmt;

/// Dynamic error with a context chain. API-compatible with `anyhow::Error`
/// for the operations this workspace performs.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Root-cause message (innermost frame).
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(src) = &cur.source {
            cur = src;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = &self.source;
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = &e.source;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into our context chain.
        let mut frames = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        let mut tail: Option<Box<Error>> = None;
        for m in frames.into_iter().rev() {
            tail = Some(Box::new(Error { msg: m, source: tail }));
        }
        Error { msg: e.to_string(), source: tail }
    }
}

/// `anyhow::Result<T>`: `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option` (the `anyhow::Context` API).
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<()> = Err(io_err()).context("reading config");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(e.root_cause(), "missing file");

        let o: Option<u32> = None;
        let e = o.context("--net is required").unwrap_err();
        assert_eq!(e.to_string(), "--net is required");

        let ok: Option<u32> = Some(3);
        assert_eq!(ok.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn debug_renders_cause_chain() {
        let e = Error::msg("root").context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }

    #[test]
    fn macros_compile_and_fire() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 7);
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert!(inner(12).unwrap_err().to_string().contains("too big"));
        assert!(inner(7).unwrap_err().to_string().contains("condition failed"));
        assert!(inner(5).unwrap_err().to_string().contains("five"));
        let e = anyhow!("{} {}", "a", "b");
        assert_eq!(e.to_string(), "a b");
    }
}
